"""Behavioral + cycle-level simulator of the VTA pipeline.

Executes an *encoded* VTA instruction stream the way the hardware does
(§2.3–§2.6): the fetch module routes instructions into three command
queues (load / compute / store); each module executes its queue in FIFO
order, predicated on RAW/WAR dependence tokens exchanged through four
dependence FIFOs; SRAM scratchpads are single-reader/single-writer.

One engine serves two roles:
  * functional simulation (unit latencies) — the oracle-checked backend;
  * cycle-level timing (TimingModel) — reproduces the latency-hiding /
    roofline study of Fig. 15.

Correctness therefore *depends on the dependence flags the runtime
emitted*, exactly as on hardware: strip the WAR tokens and double-buffered
schedules produce wrong results (tested), which is the Fig. 5 argument.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import layout
from .driver import Device
from .hwspec import HardwareSpec
from .isa import (AluInsn, AluOp, DepFlags, FinishInsn, GemmInsn, Insn,
                  IsaLayout, LoadStoreInsn, MemId, Opcode, route_queue,
                  LOAD_Q, COMPUTE_Q, STORE_Q)
from .microop import UopLayout


class DeadlockError(RuntimeError):
    pass


# ----------------------------------------------------------------------
# timing
# ----------------------------------------------------------------------
class TimingModel:
    """Latency of each CISC instruction in cycles (§2.5, §2.6)."""

    def __init__(self, spec: HardwareSpec):
        self.spec = spec

    def _dma_cycles(self, nbytes: int, write: bool) -> int:
        bpc = (self.spec.dram_wr_bytes_per_cycle if write
               else self.spec.dram_rd_bytes_per_cycle)
        return self.spec.dram_latency_cycles + int(math.ceil(nbytes / bpc))

    def latency(self, insn: Insn, spec: HardwareSpec) -> int:
        if isinstance(insn, LoadStoreInsn):
            elem = {
                MemId.UOP: spec.uop_elem_bytes, MemId.WGT: spec.wgt_elem_bytes,
                MemId.INP: spec.inp_elem_bytes, MemId.ACC: spec.acc_elem_bytes,
                MemId.OUT: spec.out_elem_bytes,
            }[insn.memory_type]
            nbytes = insn.y_size * insn.x_size * elem
            if nbytes == 0:
                return 1  # barrier noop: no DMA setup cost
            return self._dma_cycles(nbytes, write=insn.opcode == Opcode.STORE)
        if isinstance(insn, GemmInsn):
            # one tensor-tensor matrix multiply per cycle (Fig. 7)
            return max(1, insn.iter_out * insn.iter_in * (insn.uop_end - insn.uop_bgn))
        if isinstance(insn, AluInsn):
            # initiation interval >= 2: single register-file read port (§2.5)
            n = insn.iter_out * insn.iter_in * (insn.uop_end - insn.uop_bgn)
            return max(1, n * self.spec.alu_init_interval)
        return 1  # FINISH


class UnitTiming(TimingModel):
    """Functional mode: every instruction takes one cycle."""

    def latency(self, insn: Insn, spec: HardwareSpec) -> int:  # noqa: D102
        return 1


# ----------------------------------------------------------------------
# stats
# ----------------------------------------------------------------------
@dataclass
class ModuleStats:
    busy_cycles: int = 0
    insn_count: int = 0
    stall_on_token: int = 0   # cycles spent waiting for dependence tokens


@dataclass
class RunStats:
    total_cycles: int = 0
    modules: Dict[str, ModuleStats] = field(default_factory=dict)
    gemm_macs: int = 0
    alu_ops: int = 0
    dram_rd_bytes: int = 0
    dram_wr_bytes: int = 0
    tokens_pushed: int = 0
    backend: str = "simulator"   # which execution engine produced this run
    wall_time_s: float = 0.0     # host wall-clock of the engine (not cycles)
    # PallasBackend fast-path accounting (always 0 on the simulator, which
    # has no coalescer): compute instructions absorbed into lazy tiles and
    # resolved through the Pallas kernels vs. ones that fell back to the
    # eager per-uop numpy loop.
    coalesced_gemm_insns: int = 0
    coalesced_alu_insns: int = 0
    eager_gemm_insns: int = 0
    eager_alu_insns: int = 0
    # program-compiler pipelining + serving-path accounting, filled in by
    # CompiledProgram.__call__ per accelerator step: how the stream's
    # dependent-op boundaries were synchronized and how many bytes the
    # call staged into DRAM (inputs; + the stream itself when not
    # pre-staged)
    n_join_barriers: int = 0
    n_buffer_fences: int = 0
    staging_bytes_per_call: int = 0
    # cross-call persistent state (KV caches, recurrent state) resident
    # at stable DRAM addresses during this run — bytes that are neither
    # staged per call nor recycled through the arena
    persistent_bytes: int = 0
    # PallasBackend batched tile dispatch: lazily-coalesced accumulator
    # tiles resolved, and the number of kernel launches that resolved
    # them (tiles_resolved / tile_batches = batching factor)
    tiles_resolved: int = 0
    tile_batches: int = 0
    # kernel launches that went through the LUT-GEMM path (sub-byte
    # weights, memory-bound decode shapes) instead of the dense MXU GEMM
    lut_launches: int = 0
    # gang width of the run that produced this stats object: 1 for a
    # plain execute; N when the stream ran on N pooled devices in
    # lockstep (PallasBackend.execute_gang) — wall_time_s is then the
    # shared gang window, not a per-device slice
    gang_size: int = 1
    # entries evicted from the bounded decoded-stream LRU cache while
    # decoding this run's stream (backend.set_decode_cache_cap); nonzero
    # means a long-lived multi-program server is cycling more distinct
    # streams than the cache holds
    decode_evictions: int = 0
    # tuning-cache consultation of the compile that produced this
    # program (mirrored from CompiledProgram.tune_hits/tune_misses per
    # call): accel op nodes resolved from a TuningCache record vs ones
    # that fell back to the default / cycle-compare path
    tune_cache_hits: int = 0
    tune_cache_misses: int = 0

    @property
    def eager_compute_insns(self) -> int:
        """Compute instructions the PallasBackend executed on the eager
        per-uop fallback instead of the kernel fast path."""
        return self.eager_gemm_insns + self.eager_alu_insns

    @classmethod
    def merged(cls, runs: "List[RunStats]") -> "RunStats":
        """Sum the counter fields of several runs (e.g. one pooled slot's
        serving history) into one aggregate RunStats.  Cycle/wall fields
        add too — meaningful as totals, not as a single-run profile;
        ``gang_size`` reports the maximum seen."""
        out = cls(modules={})
        for r in runs:
            for f in ("total_cycles", "gemm_macs", "alu_ops",
                      "dram_rd_bytes", "dram_wr_bytes", "tokens_pushed",
                      "wall_time_s", "coalesced_gemm_insns",
                      "coalesced_alu_insns", "eager_gemm_insns",
                      "eager_alu_insns", "n_join_barriers",
                      "n_buffer_fences", "staging_bytes_per_call",
                      "tiles_resolved", "tile_batches", "lut_launches",
                      "decode_evictions", "tune_cache_hits",
                      "tune_cache_misses"):
                setattr(out, f, getattr(out, f) + getattr(r, f))
            out.gang_size = max(out.gang_size, r.gang_size)
            for nm, ms in r.modules.items():
                agg = out.modules.setdefault(nm, ModuleStats())
                agg.busy_cycles += ms.busy_cycles
                agg.insn_count += ms.insn_count
                agg.stall_on_token += ms.stall_on_token
        if runs:
            out.backend = runs[-1].backend
        return out

    @property
    def compute_utilization(self) -> float:
        """GEMM-core busy fraction — the Fig. 15 utilization metric."""
        c = self.modules.get("compute")
        if not c or self.total_cycles == 0:
            return 0.0
        return c.busy_cycles / self.total_cycles

    def gops(self, freq_mhz: float) -> float:
        if self.total_cycles == 0:
            return 0.0
        secs = self.total_cycles / (freq_mhz * 1e6)
        return 2.0 * self.gemm_macs / secs / 1e9

    @property
    def arithmetic_intensity(self) -> float:
        moved = self.dram_rd_bytes + self.dram_wr_bytes
        return 2.0 * self.gemm_macs / max(1, moved)


# ----------------------------------------------------------------------
# the engine
# ----------------------------------------------------------------------
_MODULE_NAMES = {LOAD_Q: "load", COMPUTE_Q: "compute", STORE_Q: "store"}


def _pipeline_schedule(spec: HardwareSpec, insns: List["Insn"],
                       timing: TimingModel,
                       commit=None) -> RunStats:
    """The three-module decoupled-pipeline scheduler (§2.3): each module
    consumes its command queue in FIFO order, predicated on the four
    dependence-token FIFOs; latencies come from `timing`.  `commit` (when
    given) applies each instruction's memory semantics — the behavioral
    simulator; with commit=None this is a pure cycle-accounting replay,
    which is how the Pallas engine prices the exact same stream with the
    same TimingModel (see ``replay_timing``)."""
    queues: Dict[int, List[Insn]] = {LOAD_Q: [], COMPUTE_Q: [], STORE_Q: []}
    for insn in insns:
        queues[route_queue(insn)].append(insn)

    # dependence token FIFOs (timestamps of pushes)
    l2c: List[int] = []   # RAW  load -> compute
    c2l: List[int] = []   # WAR  compute -> load
    c2s: List[int] = []   # RAW  compute -> store
    s2c: List[int] = []   # WAR  store -> compute

    def in_queues(q: int) -> List[Tuple[List[int], str]]:
        if q == LOAD_Q:
            return [(c2l, "pop_next")]
        if q == COMPUTE_Q:
            return [(l2c, "pop_prev"), (s2c, "pop_next")]
        return [(c2s, "pop_prev")]

    def out_queues(q: int) -> Dict[str, List[int]]:
        if q == LOAD_Q:
            return {"push_next": l2c}
        if q == COMPUTE_Q:
            return {"push_prev": c2l, "push_next": c2s}
        return {"push_prev": s2c}

    pc = {LOAD_Q: 0, COMPUTE_Q: 0, STORE_Q: 0}
    free_at = {LOAD_Q: 0, COMPUTE_Q: 0, STORE_Q: 0}
    stats = RunStats(modules={n: ModuleStats() for n in _MODULE_NAMES.values()})

    while True:
        # find, among modules with pending work, the one that can start
        # earliest (tokens available), and commit its instruction.
        best_q, best_start, best_insn = None, None, None
        all_done = True
        for q in (LOAD_Q, COMPUTE_Q, STORE_Q):
            if pc[q] >= len(queues[q]):
                continue
            all_done = False
            insn = queues[q][pc[q]]
            start = free_at[q]
            ok = True
            for fifo, flag in in_queues(q):
                if getattr(insn.dep, flag):
                    if not fifo:
                        ok = False
                        break
                    start = max(start, fifo[0])
            if not ok:
                continue
            if best_start is None or start < best_start:
                best_q, best_start, best_insn = q, start, insn
        if all_done:
            break
        if best_q is None:
            state = {(_MODULE_NAMES[q]): f"{pc[q]}/{len(queues[q])}"
                     for q in pc}
            raise DeadlockError(
                f"dependence deadlock: no module can issue; pcs={state} "
                f"tokens l2c={len(l2c)} c2l={len(c2l)} c2s={len(c2s)} s2c={len(s2c)}")

        q, insn = best_q, best_insn
        # consume tokens
        for fifo, flag in in_queues(q):
            if getattr(insn.dep, flag):
                fifo.pop(0)
        lat = timing.latency(insn, spec)
        finish = best_start + lat
        mstats = stats.modules[_MODULE_NAMES[q]]
        mstats.stall_on_token += best_start - free_at[q]
        mstats.busy_cycles += lat
        mstats.insn_count += 1
        free_at[q] = finish
        pc[q] += 1

        if commit is not None:
            commit(insn, stats)

        # publish outgoing tokens at completion time
        for flag, fifo in out_queues(q).items():
            if getattr(insn.dep, flag):
                fifo.append(finish)
                stats.tokens_pushed += 1

    stats.total_cycles = max(free_at.values())
    return stats


def replay_timing(spec: HardwareSpec, insns: List["Insn"],
                  timing: Optional[TimingModel] = None) -> RunStats:
    """Cycle-account an instruction list on the pipeline model without
    executing memory semantics — gives any engine (e.g. PallasBackend)
    TimingModel cycles for the exact stream it ran."""
    return _pipeline_schedule(spec, insns, timing or TimingModel(spec),
                              commit=None)


class Simulator:
    def __init__(self, spec: HardwareSpec, device: Device,
                 timing: Optional[TimingModel] = None, strict: bool = True):
        self.spec = spec
        self.device = device
        self.isa = IsaLayout(spec)
        self.uop_layout = UopLayout(spec)
        self.timing = timing or UnitTiming(spec)
        self.strict = strict  # bounds-check SRAM indices

        s = spec
        self.uop_sram = np.zeros(s.uop_depth, dtype=np.uint32)
        self.inp_sram = np.zeros((s.inp_depth, s.batch, s.block_in), dtype=np.int8)
        self.wgt_sram = np.zeros((s.wgt_depth, s.block_out, s.block_in), dtype=np.int8)
        self.acc_sram = np.zeros((s.acc_depth, s.batch, s.block_out), dtype=np.int32)
        # out buffer mirrors acc, narrowed (write-through on compute, §2.5)
        self.out_sram = np.zeros((s.acc_depth, s.batch, s.block_out), dtype=np.int8)

    # ------------------------------------------------------------------
    def run(self) -> RunStats:
        """Execute the stream at device.regs.insns (fetch → route → run)."""
        regs = self.device.regs
        if not (regs.control & 1):
            raise RuntimeError("device not started (control register bit0 clear)")
        raw = self.device.dram.read(
            regs.insns, regs.insn_count * self.isa.insn_bytes,
            dtype=np.uint64, shape=(regs.insn_count, self.isa.insn_words))
        insns = self.isa.decode_stream(raw)
        stats = self._execute(insns)
        regs.set_done()
        return stats

    # ------------------------------------------------------------------
    def _execute(self, insns: List[Insn]) -> RunStats:
        return _pipeline_schedule(self.spec, insns, self.timing,
                                  commit=self._commit)

    # ------------------------------------------------------------------
    # instruction semantics
    # ------------------------------------------------------------------
    def _commit(self, insn: Insn, stats: RunStats) -> None:
        if isinstance(insn, LoadStoreInsn):
            if insn.opcode == Opcode.LOAD:
                self._do_load(insn, stats)
            else:
                self._do_store(insn, stats)
        elif isinstance(insn, GemmInsn):
            self._do_gemm(insn, stats)
        elif isinstance(insn, AluInsn):
            self._do_alu(insn, stats)
        # FINISH: no memory effect

    def _buf(self, mem: MemId):
        s = self.spec
        if mem == MemId.UOP:
            return self.uop_sram, s.uop_elem_bytes, np.uint32, (1,)
        if mem == MemId.INP:
            return self.inp_sram, s.inp_elem_bytes, np.int8, (s.batch, s.block_in)
        if mem == MemId.WGT:
            return self.wgt_sram, s.wgt_elem_bytes, np.int8, (s.block_out, s.block_in)
        if mem == MemId.ACC:
            return self.acc_sram, s.acc_elem_bytes, np.int32, (s.batch, s.block_out)
        if mem == MemId.OUT:
            return self.out_sram, s.out_elem_bytes, np.int8, (s.batch, s.block_out)
        raise ValueError(mem)

    def _do_load(self, insn: LoadStoreInsn, stats: RunStats) -> None:
        buf, elem_bytes, dtype, eshape = self._buf(insn.memory_type)
        width = insn.x_pad_0 + insn.x_size + insn.x_pad_1
        sram = insn.sram_base
        dram = self.device.dram

        def zero_rows(n_elems: int, base: int):
            if n_elems > 0:
                buf[base:base + n_elems] = 0

        zero_rows(insn.y_pad_0 * width, sram)
        sram += insn.y_pad_0 * width
        for y in range(insn.y_size):
            zero_rows(insn.x_pad_0, sram)
            sram += insn.x_pad_0
            byte_addr = (insn.dram_base + y * insn.x_stride) * elem_bytes
            nbytes = insn.x_size * elem_bytes
            if insn.memory_type == MemId.WGT and self.spec.wgt_packed:
                # sub-byte weights: DRAM holds b-bit packed element rows
                # (elem_bytes already reflects the packing); the WGT SRAM
                # always holds sign-extended int8 — the single decode
                # point BOTH engines share (PallasBackend routes its DMA
                # through this method), keeping them bit-exact for free.
                raw = dram.read(byte_addr, nbytes)
                data = layout.unpack_wgt_elems(
                    raw.reshape(insn.x_size, elem_bytes),
                    self.spec.wgt_bits, self.spec.block_out,
                    self.spec.block_in)
            else:
                data = dram.read(byte_addr, nbytes, dtype=dtype,
                                 shape=(insn.x_size,) + (eshape if eshape != (1,) else ()))
            if insn.memory_type == MemId.UOP:
                buf[sram:sram + insn.x_size] = data
            else:
                buf[sram:sram + insn.x_size] = data.reshape((insn.x_size,) + eshape)
            stats.dram_rd_bytes += nbytes
            sram += insn.x_size
            zero_rows(insn.x_pad_1, sram)
            sram += insn.x_pad_1
        zero_rows(insn.y_pad_1 * width, sram)
        if insn.memory_type == MemId.ACC:
            # keep the out-buffer mirror coherent with direct ACC loads
            a0, a1 = insn.sram_base, sram + insn.y_pad_1 * width
            self._writethrough(a0, a1)

    def _do_store(self, insn: LoadStoreInsn, stats: RunStats) -> None:
        # STORE reads the narrowed out-buffer (§2.5 write-through mirror)
        _, elem_bytes, _, eshape = self._buf(MemId.OUT)
        dram = self.device.dram
        for y in range(insn.y_size):
            sram = insn.sram_base + y * insn.x_size
            data = self.out_sram[sram:sram + insn.x_size]
            byte_addr = (insn.dram_base + y * insn.x_stride) * elem_bytes
            dram.write(byte_addr, data)
            stats.dram_wr_bytes += insn.x_size * elem_bytes

    def _writethrough(self, lo: int, hi: int) -> None:
        self.out_sram[lo:hi] = self.acc_sram[lo:hi].astype(np.int8)  # truncating cast

    def _affine_indices(self, insn, uops) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized 2-level affine loop (Fig. 7 pseudo-code)."""
        i0 = np.arange(insn.iter_out).reshape(-1, 1, 1)
        i1 = np.arange(insn.iter_in).reshape(1, -1, 1)
        dst = np.array([u.dst for u in uops]).reshape(1, 1, -1)
        src = np.array([u.src for u in uops]).reshape(1, 1, -1)
        wgt = np.array([u.wgt for u in uops]).reshape(1, 1, -1)
        dsts = (dst + i0 * insn.dst_factor_out + i1 * insn.dst_factor_in).ravel()
        srcs = (src + i0 * insn.src_factor_out + i1 * insn.src_factor_in).ravel()
        wfo = getattr(insn, "wgt_factor_out", 0)
        wfi = getattr(insn, "wgt_factor_in", 0)
        wgts = (wgt + i0 * wfo + i1 * wfi).ravel()
        return dsts, srcs, wgts

    def _do_gemm(self, insn: GemmInsn, stats: RunStats) -> None:
        uops = self.uop_layout.decode_kernel(
            self.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        dsts, srcs, wgts = self._affine_indices(insn, uops)
        if self.strict:
            for name, idx, depth in (("dst", dsts, self.spec.acc_depth),
                                     ("src", srcs, self.spec.inp_depth),
                                     ("wgt", wgts, self.spec.wgt_depth)):
                if idx.max(initial=0) >= depth:
                    raise IndexError(f"GEMM {name} index {idx.max()} >= depth {depth}")
        if insn.reset:
            self.acc_sram[np.unique(dsts)] = 0
        else:
            # acc[dst] += inp[src] @ wgt[wgt].T, int8 x int8 -> int32
            for d, s_, w in zip(dsts, srcs, wgts):
                a = self.inp_sram[s_].astype(np.int32)
                b = self.wgt_sram[w].astype(np.int32)
                self.acc_sram[d] += a @ b.T
            stats.gemm_macs += (len(dsts) * self.spec.batch *
                                self.spec.block_in * self.spec.block_out)
        touched = np.unique(dsts)
        self.out_sram[touched] = self.acc_sram[touched].astype(np.int8)

    def _do_alu(self, insn: AluInsn, stats: RunStats) -> None:
        uops = self.uop_layout.decode_kernel(
            self.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        dsts, srcs, _ = self._affine_indices(insn, uops)
        if self.strict:
            for idx in (dsts, srcs):
                if idx.max(initial=0) >= self.spec.acc_depth:
                    raise IndexError(f"ALU index {idx.max()} >= acc depth")
        op, imm = insn.alu_opcode, insn.imm
        for d, s_ in zip(dsts, srcs):
            dstv = self.acc_sram[d].astype(np.int64)
            srcv = (np.int64(imm) if insn.use_imm
                    else self.acc_sram[s_].astype(np.int64))
            if op == AluOp.MIN:
                r = np.minimum(dstv, srcv)
            elif op == AluOp.MAX:
                r = np.maximum(dstv, srcv)
            elif op == AluOp.ADD:
                r = dstv + srcv
            elif op == AluOp.MUL:
                r = dstv * srcv
            elif op == AluOp.SHR:
                sh = srcv if insn.use_imm else srcv
                r = np.where(sh >= 0, dstv >> np.abs(sh), dstv << np.abs(sh)) \
                    if np.ndim(sh) else (dstv >> sh if sh >= 0 else dstv << (-sh))
            else:
                raise ValueError(op)
            self.acc_sram[d] = r.astype(np.int32)  # wraparound, as in RTL
        stats.alu_ops += len(dsts) * self.spec.batch * self.spec.block_out
        touched = np.unique(dsts)
        self.out_sram[touched] = self.acc_sram[touched].astype(np.int8)


def run_program(spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None,
                staged_addr: Optional[int] = None) -> RunStats:
    """Write `stream` to DRAM (or kick a pre-staged copy at
    `staged_addr` — zero allocation), set the control regs, run to
    FINISH."""
    if staged_addr is None:
        device.stage_stream(stream)
    else:
        device.kick_stream(staged_addr, stream.shape[0])
    sim = Simulator(spec, device, timing=timing)
    return sim.run()
