"""Seeded fault injection for the self-healing serving plane.

A :class:`FaultPlan` is a deterministic script of failures keyed on the
pool's accelerator-gang sequence number: *kill this slot at gang k*,
*flip a constant byte before gang k*, *delay gang k by d seconds*.
:class:`serve.DevicePool` consumes the plan at the top of every gang
execution, so a given (workload, seed) pair replays the exact same
failure history run after run — the property the chaos fuzzer flavor
and ``benchmarks/bench_chaos.py`` rely on to byte-diff every surviving
request against a fault-free serial run.

Faults model the three failure classes the recovery machinery handles:

  * ``kill``  — the slot dies mid-flight (process crash / device reset).
    Exercises slot respawn, session checkpoint/restore and stateless
    request retry.
  * ``flip``  — one bit of a constant DRAM region is corrupted
    (bit-rot, DMA scribble).  Exercises the integrity checksums and
    restage-from-pristine.
  * ``delay`` — the gang stalls for ``delay_s`` (wedged kernel, host
    hiccup).  Exercises the segment watchdog when the stall exceeds the
    TimingModel-derived deadline, and plain tail latency otherwise.

The plan records what actually fired in ``fired`` (the pool appends a
log entry per applied fault) so harnesses can reconcile injected vs
observed failures — losses must be typed and accounted, never silent.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

FAULT_KINDS = ("kill", "flip", "delay")


@dataclass(frozen=True)
class Fault:
    """One scripted failure.  ``gang`` is the pool's gang-execution
    sequence number the fault fires at; ``slot`` targets a specific
    slot id (None: the first slot of the gang it fires on)."""
    kind: str                     # kill | flip | delay
    gang: int
    slot: Optional[int] = None
    delay_s: float = 0.0          # kind == "delay"
    byte: int = 0                 # kind == "flip": offset into the
    #                               program's constant image (mod size)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in "
                             f"{FAULT_KINDS}")
        if self.gang < 0:
            raise ValueError("fault gang index must be >= 0")


@dataclass
class FaultPlan:
    """A deterministic failure script, consumed gang by gang.

        plan = FaultPlan.random(seed=7, n_gangs=200, slots=4, rate=0.10)
        pool = DevicePool(compiled, size=4, max_respawns=8, retries=3,
                          integrity=True, fault_plan=plan)

    ``take(idx)`` hands the pool every fault scheduled for gang `idx`
    (each at most once); the pool logs applied faults into ``fired``."""

    faults: List[Fault] = field(default_factory=list)
    fired: List[Dict] = field(default_factory=list)   # pool-appended log

    def __post_init__(self):
        self._by_gang: Dict[int, List[Fault]] = {}
        for f in self.faults:
            self._by_gang.setdefault(f.gang, []).append(f)

    def __len__(self) -> int:
        return len(self.faults)

    def take(self, gang_idx: int) -> List[Fault]:
        """Faults scheduled for this gang execution (consumed: a second
        call for the same index returns nothing)."""
        return self._by_gang.pop(gang_idx, [])

    def counts(self) -> Dict[str, int]:
        """Scheduled fault count by kind."""
        return dict(Counter(f.kind for f in self.faults))

    def fired_counts(self) -> Dict[str, int]:
        """Applied fault count by kind (filled in by the pool)."""
        return dict(Counter(e["kind"] for e in self.fired))

    @classmethod
    def random(cls, seed: int, n_gangs: int, slots: int,
               rate: float = 0.10,
               kinds: Sequence[str] = FAULT_KINDS,
               max_delay_s: float = 0.02) -> "FaultPlan":
        """Seeded plan: each of the first `n_gangs` gang executions
        independently draws one fault with probability `rate`, uniform
        over `kinds`, targeting a uniform slot.  Gang 0 is always left
        fault-free so every run completes at least one clean gang (jit
        warm-up / baseline sanity)."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"fault rate {rate} not in [0, 1]")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"fault kind {k!r} not in {FAULT_KINDS}")
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []
        for g in range(1, n_gangs):
            if rng.random() >= rate:
                continue
            kind = str(rng.choice(list(kinds)))
            slot = int(rng.integers(slots))
            faults.append(Fault(
                kind=kind, gang=g, slot=slot,
                delay_s=float(rng.uniform(0.0, max_delay_s))
                if kind == "delay" else 0.0,
                byte=int(rng.integers(1 << 30)) if kind == "flip" else 0))
        return cls(faults=faults)

    def describe(self) -> str:
        sched = self.counts()
        fired = self.fired_counts()
        parts = [f"{k}:{sched.get(k, 0)} scheduled/{fired.get(k, 0)} fired"
                 for k in FAULT_KINDS]
        return f"faultplan[{len(self.faults)} faults: " \
               f"{', '.join(parts)}]"
