"""VTA scheduler: tensorization, memory scopes, virtual threading (§4).

This is the TVM-analogue layer: it lowers hardware-agnostic tensor
programs (blocked matmul, 2D convolution, elementwise epilogues) onto the
VTA runtime API — tiling loops to the GEMM intrinsic (*tensorization*,
§4.2), assigning tiles to data-specialized SRAM *memory scopes* with
explicit capacity budgeting (§4.1), and lowering `virtual_threads`
contexts into a single instruction stream with explicit RAW/WAR token
insertion (*virtual threading*, §4.3 / Fig. 14).

Dependence-token protocol (per virtual thread, Fig. 12):
  load group  : pop c2l WAR token if this thread's context was read by a
                previous compute group; push l2c RAW token on last load.
  compute grp : pop l2c; on first acc write of a tile, pop s2c WAR token
                if this context was stored before; push c2l after the last
                instruction reading inp/wgt; push c2s before store.
  store       : pop c2s; push s2c.
Round-robin interleaving at tile granularity is safe because each module
executes its queue in FIFO order (the paper's information-less tokens
argument, §2.3).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import layout
from .hwspec import HardwareSpec
from .isa import AluOp, MemId, COMPUTE_Q, LOAD_Q, STORE_Q
from .runtime import Runtime, UopBuilder, UopKernel


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ----------------------------------------------------------------------
# per-virtual-thread dependence bookkeeping
# ----------------------------------------------------------------------
@dataclass
class _ThreadDeps:
    """Tracks which WAR tokens this thread has outstanding."""
    c2l_pending: bool = False   # compute has signalled loader (buffers free)
    s2c_pending: bool = False   # store has signalled compute (acc free)

    def begin_load_group(self, rt: Runtime) -> None:
        if self.c2l_pending:
            rt.dep_pop(COMPUTE_Q, LOAD_Q)
            self.c2l_pending = False

    def end_load_group(self, rt: Runtime) -> None:
        rt.dep_push(LOAD_Q, COMPUTE_Q)

    def begin_compute_group(self, rt: Runtime, pops_acc: bool) -> None:
        rt.dep_pop(LOAD_Q, COMPUTE_Q)
        if pops_acc and self.s2c_pending:
            rt.dep_pop(STORE_Q, COMPUTE_Q)
            self.s2c_pending = False

    def end_compute_group_frees_loads(self, rt: Runtime) -> None:
        rt.dep_push(COMPUTE_Q, LOAD_Q)
        self.c2l_pending = True

    def compute_to_store(self, rt: Runtime, own_insn: bool = True) -> None:
        """Signal the store module that this tile's accumulator is ready.
        The token must ride on an instruction of *this thread's* epilogue:
        dep_push attaches to the last compute-queue instruction, so a tile
        whose epilogue emitted nothing (n_alu_passes == 0, the wraparound
        store) must emit a compute noop first — otherwise the push lands
        on the interleaved peer thread's GEMM and, since a flag bit can
        only be set once, the second thread's push is silently lost and
        the stream deadlocks at its store (fuzzer-found)."""
        if not own_insn:
            rt.noop(COMPUTE_Q)
        rt.dep_push(COMPUTE_Q, STORE_Q)

    def begin_store(self, rt: Runtime) -> None:
        rt.dep_pop(COMPUTE_Q, STORE_Q)   # lands on the first store insn

    def end_store(self, rt: Runtime) -> None:
        rt.dep_push(STORE_Q, COMPUTE_Q)  # flags the last store insn
        self.s2c_pending = True


def emit_fenced_load_group(rt: Runtime, fence_pending: List[bool],
                           load_data, load_weights) -> None:
    """Emit one tile load group under the buffer-fence protocol (shared
    by every lowering pass so the token-claim invariant lives in ONE
    place): while the fence is unclaimed, the weight tile loads first —
    free-running, it overlaps the producer's epilogue/store tail in its
    disjoint wgt region — and the fence token is claimed by (gates) the
    first load of the produced data operand; afterwards the normal
    data-then-weights order resumes."""
    if fence_pending[0]:
        load_weights()
        rt.dep_pop(COMPUTE_Q, LOAD_Q)
        fence_pending[0] = False
        load_data()
    else:
        load_data()
        load_weights()


# ----------------------------------------------------------------------
# virtual-threading lowering (§4.3, Fig. 14)
# ----------------------------------------------------------------------
def interleave_virtual_threads(work_items, vt, make_program) -> None:
    """Lower a `vt`-thread data-parallel tile program into one instruction
    stream, interleaving threads at *phase* granularity.

    `make_program(item, thread)` returns a generator that emits one
    (load | compute | store) phase per `next()`.  Within each group of `vt`
    consecutive tiles, phase p of thread 0 precedes phase p of thread 1,
    etc.  This ordering is what makes VTA's information-less dependence
    tokens safe: every module executes its queue in FIFO order, so the
    k-th pop on a FIFO is always satisfied by the semantically matching
    k-th push (§2.3).  Coarser interleaving (whole tiles) breaks the
    pairing and corrupts results — covered by a regression test.
    """
    for g in range(0, len(work_items), vt):
        group = work_items[g:g + vt]
        progs = [make_program(item, t) for t, item in enumerate(group)]
        alive = list(progs)
        while alive:
            for p in list(alive):
                try:
                    next(p)
                except StopIteration:
                    alive.remove(p)


# ----------------------------------------------------------------------
# epilogue description (tensor-ALU ops applied to the acc tile)
# ----------------------------------------------------------------------
@dataclass
class Epilogue:
    """Requantization / activation epilogue executed on the tensor ALU:
      acc = acc + bias            (optional, per-output-channel)
      acc = acc >> shift          (requantize, §SHR)
      acc = min(max(acc, lo), hi) (clip; ReLU when lo=0)
    """
    bias_blocked: Optional[np.ndarray] = None  # (Nb, BATCH, BLOCK_OUT) int32
    shift: int = 0
    clip_lo: Optional[int] = -128
    clip_hi: Optional[int] = 127
    relu: bool = False

    @property
    def n_alu_passes(self) -> int:
        """Tensor-ALU passes the scheduler emits for this epilogue.  relu
        combined with a clip folds into the clip's lower bound (MAX pass),
        so it only costs its own pass when there is no clip to fold into."""
        n = 0
        if self.bias_blocked is not None:
            n += 1
        if self.shift:
            n += 1
        if self.relu and self.clip_lo is None:
            n += 1
        if self.clip_lo is not None:
            n += 2
        return n

    @property
    def folded_clip_lo(self) -> Optional[int]:
        """Effective clip lower bound with relu folded in (relu == clip at
        zero, so MAX imm=0 followed by MAX imm=lo<=0 is one MAX imm=0)."""
        if self.relu and self.clip_lo is not None:
            return max(0, self.clip_lo)
        return self.clip_lo


# ----------------------------------------------------------------------
# SRAM partitions (program-level memory scopes)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SramPartition:
    """A contiguous region of each data scratchpad assigned to one lowered
    op.  Lowering passes confine all their SRAM addressing to the
    partition, so the program compiler can keep ops with disjoint
    partitions in flight simultaneously in one stream.  The uop cache is
    exempt: uop loads share the compute queue with their consumers, so
    FIFO order already serializes them (§3.2)."""
    inp_base: int
    inp_depth: int
    wgt_base: int
    wgt_depth: int
    acc_base: int
    acc_depth: int

    @classmethod
    def full(cls, spec: HardwareSpec) -> "SramPartition":
        return cls(0, spec.inp_depth, 0, spec.wgt_depth, 0, spec.acc_depth)

    def overlaps(self, other: "SramPartition") -> bool:
        def hit(a0, an, b0, bn):
            return a0 < b0 + bn and b0 < a0 + an
        return (hit(self.inp_base, self.inp_depth,
                    other.inp_base, other.inp_depth)
                or hit(self.wgt_base, self.wgt_depth,
                       other.wgt_base, other.wgt_depth)
                or hit(self.acc_base, self.acc_depth,
                       other.acc_base, other.acc_depth))


# ----------------------------------------------------------------------
# tile-size selection (memory-scope capacity budgeting, §4.1)
# ----------------------------------------------------------------------
def choose_matmul_tiles(Mb: int, Nb: int, Kb: int, spec: HardwareSpec,
                        virtual_threads: int,
                        bias: bool = False,
                        sram: Optional[SramPartition] = None
                        ) -> Tuple[int, int, int]:
    """Pick (mt, nt, kt) block-tile sizes so each virtual-thread context
    fits its SRAM partition.  Greedy: grow kt (reduction reuse), then nt,
    then mt."""
    sram = sram or SramPartition.full(spec)
    inp_cap = sram.inp_depth // virtual_threads
    wgt_cap = sram.wgt_depth // virtual_threads
    acc_cap = sram.acc_depth // virtual_threads
    if bias:
        acc_cap //= 2  # bias tile staged in the second half of the context

    def fits(mt, nt, kt):
        return (mt * kt <= inp_cap and nt * kt <= wgt_cap
                and mt * nt <= acc_cap)

    mt, nt, kt = 1, 1, 1
    changed = True
    while changed:
        changed = False
        for grow in ("kt", "nt", "mt"):
            m2, n2, k2 = mt, nt, kt
            if grow == "kt" and kt < Kb:
                k2 = min(Kb, kt * 2)
            elif grow == "nt" and nt < Nb:
                n2 = min(Nb, nt * 2)
            elif grow == "mt" and mt < Mb:
                m2 = min(Mb, mt * 2)
            if (m2, n2, k2) != (mt, nt, kt) and fits(m2, n2, k2):
                mt, nt, kt = m2, n2, k2
                changed = True
    if not fits(mt, nt, kt):
        raise ValueError("even a 1x1x1 block tile does not fit SRAM")
    return mt, nt, kt


# ----------------------------------------------------------------------
# blocked matmul:  C[M,N] = clip((A[M,K] @ W[N,K]^T + bias) >> shift)
# ----------------------------------------------------------------------
@dataclass
class MatmulPlan:
    M: int
    N: int
    K: int
    Mb: int
    Nb: int
    Kb: int
    tiles: Tuple[int, int, int]
    a_addr: int
    w_addr: int
    c_addr: int
    bias_addr: int = -1


def lower_matmul(rt: Runtime, *, a_base: int, w_base: int, c_base: int,
                 Mb: int, Nb: int, Kb: int,
                 epilogue: Optional[Epilogue] = None, bias_base: int = -1,
                 virtual_threads: int = 2,
                 sram: Optional[SramPartition] = None,
                 transposed: bool = False,
                 a_stride: Optional[int] = None,
                 c_stride: Optional[int] = None,
                 fenced: bool = False) -> Tuple[int, int, int]:
    """Emit the blocked-matmul schedule into rt's open stream.

    This is the lowering pass behind ``schedule_matmul``: it takes
    *element* addresses of already-staged DRAM buffers, so the emitted
    stream is data-independent — rebinding the buffers with new bytes and
    re-running the same encoded stream recomputes the result (the program
    JIT-cache contract).  All SRAM addressing stays inside ``sram``.

    Normal mode addresses A row-major — elem (mb, kb) at
    ``a_base + mb*a_stride + kb`` (a_stride defaults to Kb) — and writes C
    row-major at ``c_base + mb*c_stride + nb``.  ``transposed=True``
    consumes A stored K-major — elem (kb, m) at ``a_base + kb*a_stride +
    m`` — and writes C N-major at ``c_base + nb*c_stride + m`` (strides
    default to Mb).  That is exactly the 1x1-conv fast path: a blocked
    NCHW activation plane *is* a K-major matrix over (channel-block,
    pixel), and the N-major output *is* the blocked NCHW result.  The
    schedule only moves (BATCH x block) tensor-register elements, so it is
    batch-agnostic: for batch-blocked specs the register rows carry one
    image block per element (the caller owns that interpretation — a
    batch-blocked *matrix* packed by ``pack_inp`` is row-blocked and would
    need ``transposed=False``).

    ``fenced=True`` means the program compiler emitted a
    ``Runtime.buffer_fence`` immediately before this op because operand A
    is produced by an in-flight predecessor: the first load group stages
    its *weight* tile first (free-running — it overlaps the producer's
    epilogue and store tail, SRAM partitions are disjoint), then claims
    the fence token on the first A load, which is the only instruction
    that must wait for the producer's final store.

    Returns the chosen (mt, nt, kt) tile sizes.
    """
    spec = rt.spec
    ep = epilogue or Epilogue()
    has_bias = ep.bias_blocked is not None
    if has_bias != (bias_base >= 0):
        raise ValueError("epilogue.bias_blocked and bias_base must agree")
    sram = sram or SramPartition.full(spec)
    if a_stride is None:
        a_stride = Mb if transposed else Kb
    if c_stride is None:
        c_stride = Mb if transposed else Nb
    b_base = bias_base

    mt, nt, kt = choose_matmul_tiles(Mb, Nb, Kb, spec, virtual_threads,
                                     bias=has_bias, sram=sram)
    vt = virtual_threads
    inp_ctx = sram.inp_depth // vt
    wgt_ctx = sram.wgt_depth // vt
    acc_ctx = sram.acc_depth // vt
    deps = [_ThreadDeps() for _ in range(vt)]

    n_m, n_n, n_k = _ceil_div(Mb, mt), _ceil_div(Nb, nt), _ceil_div(Kb, kt)
    tp = "T" if transposed else ""
    fence_pending = [fenced]   # claimed by the first A load emitted

    # JIT one GEMM micro-kernel per (tile-shape, context); LRU-cached.
    def gemm_kernel(mtt, ntt, ktt, acc_base, inp_base, wgt_base) -> UopKernel:
        def build(b: UopBuilder):
            if transposed:
                # SRAM holds the A tile K-major (k*mtt + m); acc is N-major
                b.loop_begin(mtt, dst_factor=1, src_factor=1, wgt_factor=0)
                b.loop_begin(ntt, dst_factor=mtt, src_factor=0,
                             wgt_factor=ktt)
                for k in range(ktt):
                    b.push(dst=acc_base, src=inp_base + k * mtt,
                           wgt=wgt_base + k)
            else:
                b.loop_begin(mtt, dst_factor=ntt, src_factor=ktt,
                             wgt_factor=0)
                b.loop_begin(ntt, dst_factor=1, src_factor=0, wgt_factor=ktt)
                for k in range(ktt):
                    b.push(dst=acc_base, src=inp_base + k, wgt=wgt_base + k)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build,
                             key=f"mm{tp}.{mtt}.{ntt}.{ktt}.{acc_base}.{inp_base}.{wgt_base}")

    def reset_kernel(mtt, ntt, acc_base) -> UopKernel:
        dfo, dfi = (1, mtt) if transposed else (ntt, 1)

        def build(b: UopBuilder):
            b.loop_begin(mtt, dst_factor=dfo, src_factor=0)
            b.loop_begin(ntt, dst_factor=dfi, src_factor=0)
            b.push(dst=acc_base, src=0)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build, key=f"rst{tp}.{mtt}.{ntt}.{acc_base}")

    def alu_tile_kernel(mtt, ntt, acc_base, src_base, src_fo, src_fi, tag) -> UopKernel:
        dfo, dfi = (1, mtt) if transposed else (ntt, 1)

        def build(b: UopBuilder):
            b.loop_begin(mtt, dst_factor=dfo, src_factor=src_fo)
            b.loop_begin(ntt, dst_factor=dfi, src_factor=src_fi)
            b.push(dst=acc_base, src=src_base)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build,
                             key=f"alu{tp}.{tag}.{mtt}.{ntt}.{acc_base}.{src_base}.{src_fo}.{src_fi}")

    def tile_program(i: int, j: int, t: int):
        """Phase generator for one macro tile executed on virtual thread t.
        Yields once per (load group | compute group | store) phase so the
        driver can interleave threads at *phase granularity* — required for
        the information-less token pairing to be safe (Fig. 14)."""
        d = deps[t]
        mtt = min(mt, Mb - i * mt)
        ntt = min(nt, Nb - j * nt)
        acc_base = sram.acc_base + t * acc_ctx
        # bias tile staged in the second half of the acc context
        bias_sram = sram.acc_base + t * acc_ctx + mt * nt
        inp_base0 = sram.inp_base + t * inp_ctx
        wgt_base0 = sram.wgt_base + t * wgt_ctx
        # self-epilogue source factors must track the dst grid layout
        self_fo, self_fi = (1, mtt) if transposed else (ntt, 1)

        first_compute_of_tile = True
        for kk in range(n_k):
            ktt = min(kt, Kb - kk * kt)
            # ---- load group ----
            d.begin_load_group(rt)

            def load_a(kk=kk, ktt=ktt):
                if transposed:
                    rt.load_buffer_2d(MemId.INP, inp_base0,
                                      a_base + (kk * kt) * a_stride + i * mt,
                                      y_size=ktt, x_size=mtt,
                                      x_stride=a_stride)
                else:
                    rt.load_buffer_2d(MemId.INP, inp_base0,
                                      a_base + (i * mt) * a_stride + kk * kt,
                                      y_size=mtt, x_size=ktt,
                                      x_stride=a_stride)

            def load_w(kk=kk, ktt=ktt):
                rt.load_buffer_2d(MemId.WGT, wgt_base0,
                                  w_base + (j * nt) * Kb + kk * kt,
                                  y_size=ntt, x_size=ktt, x_stride=Kb)

            emit_fenced_load_group(rt, fence_pending, load_a, load_w)
            d.end_load_group(rt)
            yield
            # ---- compute group ----
            d.begin_compute_group(rt, pops_acc=first_compute_of_tile)
            if first_compute_of_tile:
                rt.push_gemm(reset_kernel(mtt, ntt, acc_base), reset=True)
                if has_bias:
                    rt.load_buffer_2d(MemId.ACC, bias_sram,
                                      b_base + j * nt,
                                      y_size=1, x_size=ntt, x_stride=Nb)
                first_compute_of_tile = False
            rt.push_gemm(gemm_kernel(mtt, ntt, ktt, acc_base,
                                     inp_base0, wgt_base0))
            d.end_compute_group_frees_loads(rt)
            yield

        # ---- epilogue on the tensor ALU ----
        if has_bias:
            rt.push_alu(alu_tile_kernel(mtt, ntt, acc_base, bias_sram,
                                        0, 1, "bias"),
                        op=AluOp.ADD, use_imm=False)
        if ep.shift:
            rt.push_alu(alu_tile_kernel(mtt, ntt, acc_base, acc_base,
                                        self_fo, self_fi, "self"),
                        op=AluOp.SHR, imm=ep.shift)
        clip_lo = ep.folded_clip_lo
        if ep.relu and clip_lo is None:
            rt.push_alu(alu_tile_kernel(mtt, ntt, acc_base, acc_base,
                                        self_fo, self_fi, "self"),
                        op=AluOp.MAX, imm=0)
        if clip_lo is not None:
            rt.push_alu(alu_tile_kernel(mtt, ntt, acc_base, acc_base,
                                        self_fo, self_fi, "self"),
                        op=AluOp.MAX, imm=clip_lo)
            rt.push_alu(alu_tile_kernel(mtt, ntt, acc_base, acc_base,
                                        self_fo, self_fi, "self"),
                        op=AluOp.MIN, imm=ep.clip_hi)
        # ---- store (own phase: the peer thread's epilogue precedes this
        # store in program order, so the backend's batched tile dispatch
        # sees every peer tile fully recorded at the group's first store;
        # per-queue FIFO order — hence execution and timing — unchanged)
        d.compute_to_store(rt, own_insn=ep.n_alu_passes > 0)
        yield
        d.begin_store(rt)
        if transposed:
            rt.store_buffer_2d(acc_base,
                               c_base + (j * nt) * c_stride + i * mt,
                               y_size=ntt, x_size=mtt, x_stride=c_stride)
        else:
            rt.store_buffer_2d(acc_base,
                               c_base + (i * mt) * c_stride + j * nt,
                               y_size=mtt, x_size=ntt, x_stride=c_stride)
        d.end_store(rt)
        yield

    tiles = [(i, j) for i in range(n_m) for j in range(n_n)]
    interleave_virtual_threads(
        tiles, vt, lambda coord, t: tile_program(coord[0], coord[1], t))
    return mt, nt, kt


def schedule_matmul(rt: Runtime, a: np.ndarray, w: np.ndarray,
                    epilogue: Optional[Epilogue] = None,
                    virtual_threads: int = 2,
                    sram: Optional[SramPartition] = None) -> MatmulPlan:
    """Lower C = A @ W^T (+epilogue) onto VTA.  Returns the plan whose
    c_addr holds the blocked int8 result after rt.synchronize().

    Thin wrapper over ``lower_matmul``: stages the operands in DRAM and
    delegates the stream emission to the lowering pass."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    M, K = a.shape
    N, K2 = w.shape
    assert K == K2, (K, K2)

    ab = layout.pack_inp(a, spec)
    wb = layout.pack_wgt(w, spec)
    Mb, Kb = ab.shape[0], ab.shape[1]
    Nb = wb.shape[0]
    a_addr = rt.copy_to_device(ab, align=spec.inp_elem_bytes)
    w_addr = rt.copy_to_device(wb, align=spec.wgt_elem_bytes)
    out_bytes = Mb * Nb * spec.out_elem_bytes
    c_addr = rt.buffer_alloc(out_bytes, align=spec.out_elem_bytes)
    bias_addr = -1
    if ep.bias_blocked is not None:
        bias_addr = rt.copy_to_device(
            np.ascontiguousarray(ep.bias_blocked, dtype=np.int32),
            align=spec.acc_elem_bytes)

    tiles = lower_matmul(
        rt,
        a_base=rt.to_elem_addr(a_addr, MemId.INP),
        w_base=rt.to_elem_addr(w_addr, MemId.WGT),
        c_base=rt.to_elem_addr(c_addr, MemId.OUT),
        Mb=Mb, Nb=Nb, Kb=Kb, epilogue=ep,
        bias_base=(rt.to_elem_addr(bias_addr, MemId.ACC)
                   if bias_addr >= 0 else -1),
        virtual_threads=virtual_threads, sram=sram)

    return MatmulPlan(M=M, N=N, K=K, Mb=Mb, Nb=Nb, Kb=Kb, tiles=tiles,
                      a_addr=a_addr, w_addr=w_addr, c_addr=c_addr,
                      bias_addr=bias_addr)


def read_matmul_result(rt: Runtime, plan: MatmulPlan,
                       device=None) -> np.ndarray:
    """Read back the blocked int8 result.  `device` overrides rt.device so
    results can be read from a cloned device (cross-backend checking)."""
    spec = rt.spec
    blocked = rt.copy_from_device(
        plan.c_addr, plan.Mb * plan.Nb * spec.out_elem_bytes, np.int8,
        (plan.Mb, plan.Nb, spec.batch, spec.block_out), device=device)
    return layout.unpack_out(blocked, plan.M, plan.N, spec)


def matmul_reference(a: np.ndarray, w: np.ndarray,
                     epilogue: Optional[Epilogue] = None,
                     spec: Optional[HardwareSpec] = None) -> np.ndarray:
    """Pure-numpy oracle with identical integer semantics."""
    ep = epilogue or Epilogue()
    acc = a.astype(np.int64) @ w.astype(np.int64).T
    if ep.bias_blocked is not None and spec is not None:
        bias = ep.bias_blocked  # (Nb, BATCH, BLOCK_OUT): batch rows identical
        flat = bias[:, 0, :].reshape(-1)[:w.shape[0]]
        acc = acc + flat.astype(np.int64)[None, :]
    if ep.shift:
        acc = acc >> ep.shift
    clip_lo = ep.folded_clip_lo  # relu folds into the clip lower bound
    if ep.relu and clip_lo is None:
        acc = np.maximum(acc, 0)
    if clip_lo is not None:
        acc = np.clip(acc, clip_lo, ep.clip_hi)
    return acc.astype(np.int32).astype(np.int8)  # truncating out-store


# ----------------------------------------------------------------------
# elementwise vector ops (the Listing-1 vector-add path)
# ----------------------------------------------------------------------
def lower_vector_binop(rt: Runtime, *, a_base: int, b_base: int, c_base: int,
                       ne: int, op: AluOp = AluOp.ADD,
                       sram: Optional[SramPartition] = None) -> None:
    """Emit the chunked vector-ALU schedule (element addresses, like
    ``lower_matmul``).  Emits a self-synchronized protocol for *its own*
    SRAM traffic only; the program compiler inserts the cross-op tokens
    when composing it with other lowered ops in one stream."""
    spec = rt.spec
    sram = sram or SramPartition.full(spec)
    cap = sram.acc_depth // 2
    if cap < 1:
        raise ValueError(f"acc partition depth {sram.acc_depth} cannot "
                         "double-buffer even one vector element")
    acc0 = sram.acc_base
    stream_start = rt.stream_len   # validate only this schedule's suffix
    done = 0
    while done < ne:
        cur = min(cap, ne - done)
        # both operands staged via the compute module's ACC-load path
        rt.load_buffer_2d(MemId.ACC, acc0, a_base + done,
                          y_size=1, x_size=cur, x_stride=cur)
        rt.load_buffer_2d(MemId.ACC, acc0 + cap, b_base + done,
                          y_size=1, x_size=cur, x_stride=cur)

        def build(bu: UopBuilder, cur=cur):
            bu.loop_begin(cur, dst_factor=1, src_factor=1)
            bu.push(dst=acc0, src=acc0 + cap)
            bu.loop_end()
        rt.push_alu(rt.uop_kernel(build, key=f"vec.{op}.{cur}.{acc0}.{cap}"),
                    op=op, use_imm=False)
        rt.dep_push(COMPUTE_Q, STORE_Q)
        rt.dep_pop(COMPUTE_Q, STORE_Q)
        rt.store_buffer_2d(acc0, c_base + done,
                           y_size=1, x_size=cur, x_stride=cur)
        done += cur
        if done < ne:
            # WAR: the next chunk's ACC loads overwrite rows this store is
            # still draining.  Only emitted when another chunk follows, so
            # the stream ends with every dependence FIFO at net zero.
            rt.dep_push(STORE_Q, COMPUTE_Q)
            rt.dep_pop(STORE_Q, COMPUTE_Q)
    rt.validate_stream(require_net_zero=True, start=stream_start)


def schedule_vector_binop(rt: Runtime, a: np.ndarray, b: np.ndarray,
                          op: AluOp = AluOp.ADD,
                          sram: Optional[SramPartition] = None
                          ) -> Tuple[int, Tuple[int, ...]]:
    """C = a (op) b over int32 vectors via the tensor ALU (Listing 1).
    Thin wrapper over ``lower_vector_binop``."""
    spec = rt.spec
    lane = spec.batch * spec.block_out
    a = np.asarray(a, np.int32).ravel()
    b = np.asarray(b, np.int32).ravel()
    n = a.size
    ne = _ceil_div(n, lane)
    ab = np.zeros((ne, spec.batch, spec.block_out), np.int32)
    bb = np.zeros_like(ab)
    ab.reshape(-1)[:n] = a
    bb.reshape(-1)[:n] = b
    a_addr = rt.copy_to_device(ab, align=spec.acc_elem_bytes)
    b_addr = rt.copy_to_device(bb, align=spec.acc_elem_bytes)
    c_addr = rt.buffer_alloc(ne * spec.out_elem_bytes, align=spec.out_elem_bytes)
    lower_vector_binop(rt,
                       a_base=rt.to_elem_addr(a_addr, MemId.ACC),
                       b_base=rt.to_elem_addr(b_addr, MemId.ACC),
                       c_base=rt.to_elem_addr(c_addr, MemId.OUT),
                       ne=ne, op=op, sram=sram)
    return c_addr, (ne, spec.batch, spec.block_out)


def read_vector_result(rt: Runtime, c_addr: int, shape: Tuple[int, ...],
                       n: int, device=None) -> np.ndarray:
    ne = shape[0]
    spec = rt.spec
    blocked = rt.copy_from_device(c_addr, ne * spec.out_elem_bytes, np.int8,
                                  (ne, spec.batch, spec.block_out),
                                  device=device)
    return blocked.reshape(-1)[:n]
