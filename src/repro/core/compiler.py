"""Graph-to-stream compiler support: SRAM liveness, cross-op dependence
tokens, and stream segmentation.

The paper's JIT runtime lowers whole model graphs into task-ISA streams
(§3, Fig. 16) instead of synchronizing per op.  The pieces that make that
safe live here:

  * a **liveness pass** over scratchpad regions: each lowered op gets a
    :class:`~repro.core.scheduler.SramPartition`; ops whose partitions are
    disjoint *and* that exchange no data through DRAM stay in flight
    together (their load/compute/store phases interleave in one stream);

  * **cross-op dependence tokens**: dependent ops — or ops forced to reuse
    scratchpad — are separated by a full ``join_barrier`` (drain stale
    tokens, rendezvous on the compute module, resume).  Overlapping
    independent ops still get a ``drain_dep_tokens`` partial fence, because
    VTA tokens are information-less: a predecessor's unconsumed tokens
    would shift the successor's push/pop pairing one generation early and
    silently break its own WAR protocol;

  * **segmentation**: ``cpu_only`` graph nodes split the stream into
    accelerator segments with host steps between them — real heterogeneous
    execution, the Fig. 16 offload split executed rather than modelled.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .runtime import Runtime
from .scheduler import SramPartition


@dataclass
class AccelStep:
    """One finalized accelerator segment: a single encoded task-ISA stream
    any execution backend can run."""
    stream: np.ndarray
    insn_count: int
    n_barriers: int
    n_drains: int
    node_ids: Tuple[int, ...]


@dataclass
class CpuStep:
    """One host-side op executed between accelerator segments."""
    node_id: int


def _largest_gap(depth: int, taken: Sequence[Tuple[int, int]]) -> Tuple[int, int]:
    """Largest free (base, size) interval in [0, depth) given taken
    (base, size) intervals."""
    ivs = sorted((b, b + s) for b, s in taken)
    best = (0, 0)
    cur = 0
    for b, e in ivs:
        if b - cur > best[1]:
            best = (cur, b - cur)
        cur = max(cur, e)
    if depth - cur > best[1]:
        best = (cur, depth - cur)
    return best


class SegmentBuilder:
    """Accumulates lowered ops into one instruction stream, deciding per op
    whether it can overlap the ops still in flight (liveness) or needs a
    token fence first."""

    def __init__(self, rt: Runtime):
        self.rt = rt
        self.live: List[Tuple[SramPartition, int]] = []  # (partition, out)
        self.n_barriers = 0
        self.n_drains = 0
        self.node_ids: List[int] = []

    # ------------------------------------------------------------------
    def _gap_partition(self) -> Optional[SramPartition]:
        spec = self.rt.spec
        parts = [p for p, _ in self.live]
        gi = _largest_gap(spec.inp_depth, [(p.inp_base, p.inp_depth)
                                           for p in parts])
        gw = _largest_gap(spec.wgt_depth, [(p.wgt_base, p.wgt_depth)
                                           for p in parts])
        ga = _largest_gap(spec.acc_depth, [(p.acc_base, p.acc_depth)
                                           for p in parts])
        if min(gi[1], gw[1], ga[1]) == 0:
            return None
        return SramPartition(gi[0], gi[1], gw[0], gw[1], ga[0], ga[1])

    @staticmethod
    def _half_partition(spec) -> SramPartition:
        return SramPartition(0, spec.inp_depth // 2, 0, spec.wgt_depth // 2,
                             0, spec.acc_depth // 2)

    # ------------------------------------------------------------------
    def place(self, node_id: int, *, reads: Set[int], out_addr: int,
              lower: Callable[[SramPartition], None],
              wants_overlap: bool = False) -> None:
        """Emit one op into the open stream.

        reads: DRAM buffer addresses produced by earlier ops (graph inputs
        are excluded — they are staged before the stream runs and cannot
        race with it).  lower(sram) must choose its tiles *before* emitting
        any instruction and raise ValueError if the partition is too small,
        so a failed attempt leaves the stream unchanged."""
        rt = self.rt
        spec = rt.spec
        self.node_ids.append(node_id)
        live_outs = {a for _, a in self.live}
        if not (reads & live_outs):
            if self.live:
                part = self._gap_partition()
                if part is not None:
                    try:
                        # stale-token fence: predecessors' unconsumed
                        # tokens must not alias this op's own pairing
                        rt.drain_dep_tokens()
                        self.n_drains += 1
                        lower(part)
                        self.live.append((part, out_addr))
                        return
                    except ValueError:
                        pass  # minimum tile does not fit the gap
            elif wants_overlap:
                # first op of an overlappable pair: take half of each
                # scratchpad so the independent successor has a region
                part = self._half_partition(spec)
                try:
                    lower(part)
                    self.live.append((part, out_addr))
                    return
                except ValueError:
                    pass
            else:
                part = SramPartition.full(spec)
                lower(part)
                self.live.append((part, out_addr))
                return
        # dependent op, or no usable disjoint region: full rendezvous,
        # then the whole scratchpad is ours again
        if len(rt.stream):
            rt.join_barrier()
            self.n_barriers += 1
        self.live = []
        part = SramPartition.full(spec)
        lower(part)
        self.live.append((part, out_addr))

    # ------------------------------------------------------------------
    def finish(self) -> Optional[AccelStep]:
        """Finalize the open stream (FINISH + static token validation +
        binary encoding) into an AccelStep; None if nothing was emitted."""
        if not len(self.rt.stream):
            return None
        stream = self.rt.finalize_stream()
        step = AccelStep(stream=stream, insn_count=stream.shape[0],
                         n_barriers=self.n_barriers, n_drains=self.n_drains,
                         node_ids=tuple(self.node_ids))
        self.rt.reset_stream()
        self.live = []
        self.n_barriers = 0
        self.n_drains = 0
        self.node_ids = []
        return step
