"""Graph-to-stream compiler support: SRAM liveness, cross-op dependence
tokens, and stream segmentation.

The paper's JIT runtime lowers whole model graphs into task-ISA streams
(§3, Fig. 16) instead of synchronizing per op.  The pieces that make that
safe live here:

  * a **liveness pass** over scratchpad regions: each lowered op gets a
    :class:`~repro.core.scheduler.SramPartition`; ops whose partitions are
    disjoint *and* that exchange no data through DRAM stay in flight
    together (their load/compute/store phases interleave in one stream);

  * **cross-op dependence tokens**: dependent ops — or ops forced to reuse
    scratchpad — are separated by a *buffer-granular fence*
    (``Runtime.buffer_fence``): only the consumer's loads of the produced
    buffer wait on the producer's final store, so the consumer's first
    weight tile DMAs while the producer's epilogue and store tail drain —
    dependent layers double-buffer across the op boundary.
    ``fence_mode="barrier"`` keeps the old full ``join_barrier``
    rendezvous as the A/B baseline.  Overlapping independent ops still get
    a ``drain_dep_tokens`` partial fence, because VTA tokens are
    information-less: a predecessor's unconsumed tokens would shift the
    successor's push/pop pairing one generation early and silently break
    its own WAR protocol;

  * **segmentation**: ``cpu_only`` graph nodes split the stream into
    accelerator segments with host steps between them — real heterogeneous
    execution, the Fig. 16 offload split executed rather than modelled.

Every fence and barrier is also a **DRAM liveness point**: all earlier
ops' loads are complete once it retires, so the program builder's arena
allocator (:class:`ArenaAllocator` below, driven by ``program._build``)
recycles dead intermediate buffers exactly at these placements —
``out_alloc(sync=True)``.

The arena serves *intermediates only*.  Buffers in the **persistent**
liveness class — graph inputs, program outputs, and
``Program.persistent()`` state that survives across calls (KV caches,
recurrent state) — are allocated once at stable addresses outside the
arena and are never recycled: a persistent buffer's bytes written by
call N must still be there when call N+1's stream reads them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Set, Tuple

import numpy as np

from .isa import COMPUTE_Q, LOAD_Q
from .runtime import Runtime
from .scheduler import SramPartition

FENCE_MODES = ("buffer", "barrier")


@dataclass(frozen=True)
class ImageRange:
    """The half-open DRAM span ``[lo, hi)`` one compiled program's staged
    image occupies: buffers, constants, arena, persistent state and
    pre-staged streams all land inside it.  Co-staged programs
    (``program.compile_multi``) compile against ONE shared device whose
    bump allocator hands each program a disjoint range — the property
    that lets a single pool slot hold a heterogeneous program mix in one
    resident image with every baked address still valid."""
    lo: int
    hi: int

    @property
    def nbytes(self) -> int:
        return self.hi - self.lo

    def overlaps(self, other: "ImageRange") -> bool:
        return self.lo < other.hi and other.lo < self.hi


@dataclass
class AccelStep:
    """One finalized accelerator segment: a single encoded task-ISA stream
    any execution backend can run.  ``staged_addr`` is the stream's
    pre-staged DRAM address (-1 = not pre-staged); ``fence_edges`` are the
    (producer_node, consumer_node) pairs joined by a buffer fence."""
    stream: np.ndarray
    insn_count: int
    n_barriers: int
    n_drains: int
    node_ids: Tuple[int, ...]
    n_fences: int = 0
    fence_edges: Tuple[Tuple[int, int], ...] = ()
    staged_addr: int = -1


@dataclass
class CpuStep:
    """One host-side op executed between accelerator segments."""
    node_id: int


class ArenaAllocator:
    """DRAM liveness arena for intermediate buffers.

    Best-fit over the free list with **block splitting**: when a dead
    block is larger than the request, only the aligned prefix is handed
    out and the tail returns to the free pool immediately — long-lived
    residents (e.g. a graph whose early layers produced one huge
    intermediate) no longer pin their whole birth size against later
    small allocations.  All sizes are rounded up to ``align`` at birth so
    a split tail is itself a valid, aligned block.

    The caller drives liveness: :meth:`alloc` records each block's last
    reader, :meth:`release_dead` (called only at fence / barrier /
    segment sync points, where every earlier op's loads are ordered
    before any later op's stores) returns expired blocks to the free
    list.  Persistent buffers never enter the arena — they are allocated
    by the program builder directly at stable addresses.
    """

    def __init__(self, alloc_fn: Callable[[int, int], int], align: int):
        self.align = align
        self._alloc = alloc_fn                  # (nbytes, align) -> addr
        self.free: List[Tuple[int, int]] = []           # (size, addr)
        # (last_use, size, addr): allocated, awaiting its last reader
        self.pending: List[Tuple[int, int, int]] = []
        self.bytes = 0            # fresh DRAM backing the arena
        self.blocks = 0
        self.reuse_hits = 0       # requests served from a dead block
        self.splits = 0           # dead blocks split on best-fit reuse
        self.intermediates = 0    # total requests

    def release_dead(self, before_idx: int) -> None:
        """Return blocks whose last reader precedes `before_idx` to the
        free pool.  Only call at sync points — recycling a buffer whose
        reader is still in flight would race through DRAM."""
        still = []
        for lu, size, addr in self.pending:
            if lu < before_idx:
                self.free.append((size, addr))
            else:
                still.append((lu, size, addr))
        self.pending[:] = still

    def alloc(self, nbytes: int, last_use: int) -> int:
        """One intermediate buffer of `nbytes`, live until `last_use`."""
        self.intermediates += 1
        need = -(-nbytes // self.align) * self.align
        best = None
        for bi, (size, _) in enumerate(self.free):
            if size >= need and (best is None
                                 or size < self.free[best][0]):
                best = bi
        if best is not None:
            size, addr = self.free.pop(best)
            self.reuse_hits += 1
            if size - need >= self.align:
                # split: hand out the aligned prefix, free the tail
                self.free.append((size - need, addr + need))
                self.splits += 1
                size = need
            self.pending.append((last_use, size, addr))
            return addr
        addr = self._alloc(need, self.align)
        self.bytes += need
        self.blocks += 1
        self.pending.append((last_use, need, addr))
        return addr


def _largest_gap(depth: int, taken: Sequence[Tuple[int, int]]) -> Tuple[int, int]:
    """Largest free (base, size) interval in [0, depth) given taken
    (base, size) intervals."""
    ivs = sorted((b, b + s) for b, s in taken)
    best = (0, 0)
    cur = 0
    for b, e in ivs:
        if b - cur > best[1]:
            best = (cur, b - cur)
        cur = max(cur, e)
    if depth - cur > best[1]:
        best = (cur, depth - cur)
    return best


class SegmentBuilder:
    """Accumulates lowered ops into one instruction stream, deciding per op
    whether it can overlap the ops still in flight (liveness), ride a
    buffer fence off a producer, or needs a full barrier first."""

    def __init__(self, rt: Runtime, fence_mode: str = "buffer"):
        if fence_mode not in FENCE_MODES:
            raise ValueError(f"fence_mode {fence_mode!r} not in {FENCE_MODES}")
        self.rt = rt
        self.fence_mode = fence_mode
        # (partition, out_addr, node_id) per op still in flight
        self.live: List[Tuple[SramPartition, int, int]] = []
        self.n_barriers = 0
        self.n_drains = 0
        self.n_fences = 0
        self.node_ids: List[int] = []
        self.fence_edges: List[Tuple[int, int]] = []

    # ------------------------------------------------------------------
    def _gap_partition(self, parts: Optional[Sequence[SramPartition]] = None
                       ) -> Optional[SramPartition]:
        spec = self.rt.spec
        if parts is None:
            parts = [p for p, _, _ in self.live]
        gi = _largest_gap(spec.inp_depth, [(p.inp_base, p.inp_depth)
                                           for p in parts])
        gw = _largest_gap(spec.wgt_depth, [(p.wgt_base, p.wgt_depth)
                                           for p in parts])
        ga = _largest_gap(spec.acc_depth, [(p.acc_base, p.acc_depth)
                                           for p in parts])
        if min(gi[1], gw[1], ga[1]) == 0:
            return None
        return SramPartition(gi[0], gi[1], gw[0], gw[1], ga[0], ga[1])

    @staticmethod
    def _half_partition(spec) -> SramPartition:
        return SramPartition(0, spec.inp_depth // 2, 0, spec.wgt_depth // 2,
                             0, spec.acc_depth // 2)

    @staticmethod
    def _wgt_hedged(spec) -> SramPartition:
        """Full inp/acc, first half of the weight buffer: what a producer
        takes when its *successor depends on it* in fence mode, so the
        successor can pre-stage its first weight tile into the other half
        while this op's store tail drains (cross-boundary
        double-buffering of the weight scratchpad)."""
        return SramPartition(0, spec.inp_depth, 0, spec.wgt_depth // 2,
                             0, spec.acc_depth)

    def _wgt_gap_partition(self, parts: Sequence[SramPartition]
                           ) -> Optional[SramPartition]:
        """Full inp/acc plus the largest free weight-buffer interval not
        claimed by `parts` — the fenced consumer's partition.  Only the
        weight region must be disjoint from the retiring producers: the
        consumer's single pre-fence instruction is its first weight-tile
        load, while its inp/acc traffic is ordered behind the fence token
        (load queue) or the fence noops (compute queue)."""
        spec = self.rt.spec
        gw = _largest_gap(spec.wgt_depth, [(p.wgt_base, p.wgt_depth)
                                           for p in parts])
        if gw[1] == 0:
            return None
        return SramPartition(0, spec.inp_depth, gw[0], gw[1],
                             0, spec.acc_depth)

    # ------------------------------------------------------------------
    def place(self, node_id: int, *, reads: Set[int],
              out_alloc: Callable[[bool], int],
              lower: Callable[..., None],
              wants_overlap: bool = False,
              succ_dependent: bool = False,
              uses_load_queue: bool = True) -> None:
        """Emit one op into the open stream.

        reads: DRAM buffer addresses produced by earlier ops (graph inputs
        are excluded — they are staged before the stream runs and cannot
        race with it).  out_alloc(sync) assigns the op's output DRAM
        buffer and returns its address; sync=True is passed exactly when a
        fence/barrier orders this op's stores after every earlier op's
        loads, so the arena may recycle dead intermediates.  lower(sram,
        fenced=...) must choose its tiles *before* emitting any
        instruction and raise ValueError if the partition is too small, so
        a failed attempt leaves the stream unchanged.  succ_dependent
        marks ops whose in-segment successor reads their output: in fence
        mode they hedge half the weight buffer so the successor's first
        weight tile can pre-stage into the other half.  uses_load_queue is
        False for ops whose operand traffic rides the compute queue (ACC
        loads, e.g. vector binops): compute-FIFO order behind the fence
        noops already serializes them, no c2l token needed."""
        rt = self.rt
        spec = rt.spec
        self.node_ids.append(node_id)
        live_outs = {a for _, a, _ in self.live}
        if not (reads & live_outs):
            if self.live:
                part = self._gap_partition()
                if part is not None:
                    try:
                        # stale-token fence: predecessors' unconsumed
                        # tokens must not alias this op's own pairing
                        rt.drain_dep_tokens()
                        self.n_drains += 1
                        out = out_alloc(False)
                        lower(part, fenced=False)
                        self.live.append((part, out, node_id))
                        return
                    except ValueError:
                        pass  # minimum tile does not fit the gap
            elif wants_overlap:
                # first op of an overlappable pair: take half of each
                # scratchpad so the independent successor has a region
                part = self._half_partition(spec)
                try:
                    out = out_alloc(False)
                    lower(part, fenced=False)
                    self.live.append((part, out, node_id))
                    return
                except ValueError:
                    pass
            else:
                out = out_alloc(False)
                if self.fence_mode == "buffer" and succ_dependent:
                    try:
                        part = self._wgt_hedged(spec)
                        lower(part, fenced=False)
                        self.live.append((part, out, node_id))
                        return
                    except ValueError:
                        pass  # does not fit half the wgt buffer
                part = SramPartition.full(spec)
                lower(part, fenced=False)
                self.live.append((part, out, node_id))
                return
        # dependent op, or no usable disjoint region
        if self.fence_mode == "buffer" and rt.stream_len:
            self._place_fenced(node_id, reads, out_alloc, lower,
                               uses_load_queue, succ_dependent)
            return
        # full rendezvous; the whole scratchpad is ours again
        if rt.stream_len:
            rt.join_barrier()
            self.n_barriers += 1
        part = SramPartition.full(spec)
        out = out_alloc(True)
        lower(part, fenced=False)
        self.live = [(part, out, node_id)]

    # ------------------------------------------------------------------
    def _place_fenced(self, node_id: int, reads: Set[int],
                      out_alloc: Callable[[bool], int],
                      lower: Callable[..., None],
                      uses_load_queue: bool,
                      succ_dependent: bool = False) -> None:
        """Dependent-op placement, fence mode: emit a buffer fence, then
        try to lower the consumer with its weight region disjoint from
        the retiring producers' so its first weight tile can DMA *before*
        the fence token (overlapping the producer's epilogue and store
        tail).  If no such region fits, the fence token gates the
        consumer's very first load instead and it gets the full
        scratchpad — still cheaper than a barrier (stores never gated, no
        load/compute rendezvous)."""
        rt = self.rt
        self.fence_edges.extend(
            (nid, node_id) for _, a, nid in self.live if a in reads)
        rt.buffer_fence(consumer_loads=uses_load_queue)
        self.n_fences += 1
        old_parts = [p for p, _, _ in self.live]
        self.live = []
        out = out_alloc(True)
        if uses_load_queue and old_parts:
            part = self._wgt_gap_partition(old_parts)
            if part is not None:
                try:
                    lower(part, fenced=True)
                    self.live = [(part, out, node_id)]
                    return
                except ValueError:
                    pass  # minimum tile does not fit the gap
        if uses_load_queue:
            # no preload region: claim the fence token on the very first
            # load (whatever it is) — everything after it is ordered
            rt.dep_pop(COMPUTE_Q, LOAD_Q)
        if succ_dependent:
            try:
                part = self._wgt_hedged(rt.spec)
                lower(part, fenced=False)
                self.live = [(part, out, node_id)]
                return
            except ValueError:
                pass  # does not fit half the wgt buffer
        part = SramPartition.full(rt.spec)
        try:
            lower(part, fenced=False)
        except ValueError:
            # full-scratchpad lowering failed (op genuinely does not
            # fit); leave no dangling fence pop behind
            rt.clear_pending_pop(LOAD_Q)
            raise
        self.live = [(part, out, node_id)]

    # ------------------------------------------------------------------
    def finish(self) -> Optional[AccelStep]:
        """Finalize the open stream (FINISH + static token validation +
        binary encoding) into an AccelStep; None if nothing was emitted."""
        if not self.rt.stream_len:
            return None
        stream = self.rt.finalize_stream()
        step = AccelStep(stream=stream, insn_count=stream.shape[0],
                         n_barriers=self.n_barriers, n_drains=self.n_drains,
                         n_fences=self.n_fences,
                         fence_edges=tuple(self.fence_edges),
                         node_ids=tuple(self.node_ids))
        self.rt.reset_stream()
        self.live = []
        self.n_barriers = 0
        self.n_drains = 0
        self.n_fences = 0
        self.node_ids = []
        self.fence_edges = []
        return step
