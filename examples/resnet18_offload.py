"""End-to-end driver #1 (paper §5, Fig. 16): ResNet-18 conv offload onto VTA.

Part 1 — per-layer study (unchanged semantics): quantize one ResNet conv
layer end to end, lower it with the direct-conv scheduler (2D padded DMA,
no host im2col), execute on the simulator, check the result against the
integer oracle, and report cycle-level timing.

Part 2 — heterogeneous execution, *executed* rather than modelled: a
C1-style `cpu_only` stem, the anchor conv layer, and a 1x1 pointwise conv
are compiled by the program-level JIT into host steps + ONE task-ISA
stream, then run end to end on BOTH execution backends (simulator oracle
and the Pallas fast path) and checked bit-exact against the chained
reference — the Fig. 16 CPU/accelerator split as a real program.  The
chain is channel-scaled (<=128) so the simulator side stays quick.

Run:  PYTHONPATH=src python examples/resnet18_offload.py [layer]
"""
import sys
import time

import numpy as np

from repro.core import Program, hwspec, quantize as q
from repro.core.backend import assert_fast_path
from repro.core.conv import ConvShape, conv2d_reference, read_conv_result, \
    schedule_conv2d
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue
from repro.core.simulator import TimingModel
from repro.core.workloads import layer_by_name


def per_layer_study(name: str) -> None:
    layer = layer_by_name(name)
    shape = layer.shape
    spec = hwspec.pynq()
    print(f"{name}: {shape.ic}->{shape.oc} ch, {shape.h}x{shape.w}, "
          f"k={shape.kh} s={shape.stride}  ({shape.gops:.2f} GOP)")

    rng = np.random.default_rng(0)
    x_f = rng.normal(size=(shape.n, shape.ic, shape.h, shape.w)) \
        .astype(np.float32)
    w_f = (rng.normal(size=(shape.oc, shape.ic, shape.kh, shape.kw))
           / np.sqrt(shape.ic * shape.kh * shape.kw)).astype(np.float32)

    qx, qw = q.calibrate(x_f), q.calibrate(w_f)
    xq, wq = q.quantize(x_f, qx), q.quantize(w_f, qw)

    rt = Runtime(spec)
    ep = Epilogue(shift=0, relu=False)
    plan = schedule_conv2d(rt, xq, wq, shape, epilogue=ep, virtual_threads=2)
    stats = rt.synchronize(timing=TimingModel(spec))
    got = read_conv_result(rt, plan)
    want = conv2d_reference(xq, wq, shape, epilogue=ep)
    assert np.array_equal(got, want), "simulator diverged!"

    secs = stats.total_cycles / (spec.freq_mhz * 1e6)
    print(f"exact on VTA; {stats.total_cycles:,} cycles = {secs * 1e3:.1f} ms "
          f"@ {spec.freq_mhz:.0f} MHz")
    print(f"achieved {stats.gops(spec.freq_mhz):.1f} / {spec.peak_gops:.1f} "
          f"GOPS  (utilization {stats.compute_utilization:.1%})")
    print(f"DRAM traffic: {stats.dram_rd_bytes / 1e6:.1f} MB read, "
          f"{stats.dram_wr_bytes / 1e6:.1f} MB written "
          f"(intensity {stats.arithmetic_intensity:.1f} ops/B)")


def heterogeneous_chain(name: str) -> None:
    """cpu stem -> anchor conv -> 1x1 conv, one Program, two engines."""
    anchor = layer_by_name(name).shape
    spec = hwspec.pynq()
    # channel-scale the chain so the behavioral simulator stays quick
    ic = min(anchor.ic, 128)
    oc = min(anchor.oc, 128)
    h = anchor.h
    stem = ConvShape(n=1, h=2 * h, w=2 * h, ic=3, oc=ic,
                     kh=7, kw=7, stride=2, pad=3)          # C1-style, CPU
    body = ConvShape(n=1, h=h, w=h, ic=ic, oc=oc, kh=anchor.kh,
                     kw=anchor.kw, stride=1, pad=anchor.kh // 2)
    point = ConvShape(n=1, h=body.oh, w=body.ow, ic=oc, oc=oc,
                      kh=1, kw=1, stride=1, pad=0)         # C3-style, GEMM
    ep = Epilogue(shift=5, relu=True)

    rng = np.random.default_rng(1)
    x = rng.integers(-64, 64, size=(1, 3, stem.h, stem.w), dtype=np.int8)
    k1 = rng.integers(-8, 8, size=(stem.oc, 3, 7, 7), dtype=np.int8)
    k2 = rng.integers(-8, 8, size=(body.oc, body.ic, body.kh, body.kw),
                      dtype=np.int8)
    k3 = rng.integers(-8, 8, size=(point.oc, point.ic, 1, 1), dtype=np.int8)

    prog = Program(spec)
    t = prog.conv2d(prog.input("x", x.shape), prog.input("k1", k1.shape),
                    stem, epilogue=ep, cpu_only=True)
    t = prog.conv2d(t, prog.input("k2", k2.shape), body, epilogue=ep)
    prog.conv2d(t, prog.input("k3", k3.shape), point, epilogue=ep)
    t0 = time.perf_counter()
    compiled = prog.compile()
    print(f"\nheterogeneous chain ({name}-scaled): {compiled.describe()}")
    print(f"compiled in {(time.perf_counter() - t0) * 1e3:.0f} ms; "
          f"{len(compiled.cpu_steps)} cpu step(s) + "
          f"{len(compiled.accel_steps)} accelerator stream(s), "
          f"{compiled.insn_count} instructions")

    ref = conv2d_reference(x, k1, stem, epilogue=ep)
    ref = conv2d_reference(ref, k2, body, epilogue=ep)
    ref = conv2d_reference(ref, k3, point, epilogue=ep)

    for backend in ("simulator", "pallas"):
        t0 = time.perf_counter()
        got = compiled(backend=backend, x=x, k1=k1, k2=k2, k3=k3)
        dt = time.perf_counter() - t0
        assert np.array_equal(got, ref), f"{backend} diverged!"
        print(f"  {backend}: exact end-to-end in {dt * 1e3:.0f} ms")
        if backend == "pallas":
            # every conv — including the kh*kw>1 body — must stay on the
            # coalesced vta_gemm fast path (describe() shows the modes)
            assert_fast_path(compiled.last_stats)
            coal = sum(s.coalesced_gemm_insns for s in compiled.last_stats)
            eager = sum(s.eager_gemm_insns for s in compiled.last_stats)
            print(f"    fast path: {coal} GEMM insns coalesced, "
                  f"{eager} eager fallbacks")
    # second invocation: rebinds DRAM inputs, no re-scheduling
    x2 = rng.integers(-64, 64, size=x.shape, dtype=np.int8)
    t0 = time.perf_counter()
    compiled(x=x2, k1=k1, k2=k2, k3=k3)
    print(f"  rerun with new data (stream cache hit): "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")


def main() -> None:
    name = sys.argv[1] if len(sys.argv) > 1 else "C9"
    per_layer_study(name)
    heterogeneous_chain(name)


if __name__ == "__main__":
    main()
