"""End-to-end driver #1 (paper §5): ResNet-18 conv offload onto VTA.

Quantizes one ResNet conv layer end to end (weights AND activations),
lowers it to a VTA instruction stream with the direct-conv scheduler
(2D padded DMA, no host im2col), executes on the simulator, and checks
the dequantized result against the float reference — then reports the
cycle-level timing like Fig. 16.

Run:  PYTHONPATH=src python examples/resnet18_offload.py [layer]
"""
import sys

import numpy as np

from repro.core import hwspec, quantize as q
from repro.core.conv import conv2d_reference, read_conv_result, schedule_conv2d
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue
from repro.core.simulator import TimingModel
from repro.core.workloads import layer_by_name


def main() -> None:
    name = sys.argv[1] if len(sys.argv) > 1 else "C9"
    layer = layer_by_name(name)
    shape = layer.shape
    spec = hwspec.pynq()
    print(f"{name}: {shape.ic}->{shape.oc} ch, {shape.h}x{shape.w}, "
          f"k={shape.kh} s={shape.stride}  ({shape.gops:.2f} GOP)")

    rng = np.random.default_rng(0)
    x_f = rng.normal(size=(shape.n, shape.ic, shape.h, shape.w)) \
        .astype(np.float32)
    w_f = (rng.normal(size=(shape.oc, shape.ic, shape.kh, shape.kw))
           / np.sqrt(shape.ic * shape.kh * shape.kw)).astype(np.float32)

    qx, qw = q.calibrate(x_f), q.calibrate(w_f)
    xq, wq = q.quantize(x_f, qx), q.quantize(w_f, qw)

    rt = Runtime(spec)
    ep = Epilogue(shift=0, relu=False)
    plan = schedule_conv2d(rt, xq, wq, shape, epilogue=ep, virtual_threads=2)
    stats = rt.synchronize(timing=TimingModel(spec))
    got = read_conv_result(rt, plan)
    want = conv2d_reference(xq, wq, shape, epilogue=ep)
    assert np.array_equal(got, want), "simulator diverged!"

    secs = stats.total_cycles / (spec.freq_mhz * 1e6)
    print(f"exact on VTA; {stats.total_cycles:,} cycles = {secs * 1e3:.1f} ms "
          f"@ {spec.freq_mhz:.0f} MHz")
    print(f"achieved {stats.gops(spec.freq_mhz):.1f} / {spec.peak_gops:.1f} "
          f"GOPS  (utilization {stats.compute_utilization:.1%})")
    print(f"DRAM traffic: {stats.dram_rd_bytes / 1e6:.1f} MB read, "
          f"{stats.dram_wr_bytes / 1e6:.1f} MB written "
          f"(intensity {stats.arithmetic_intensity:.1f} ops/B)")


if __name__ == "__main__":
    main()
