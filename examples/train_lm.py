"""End-to-end driver #2: train an LM for a few hundred steps.

Uses the production Trainer (checkpointing, watchdog, optimizer) on a
reduced config so it runs on CPU in minutes; pass --full on real
hardware.  Loss must drop well below ln(vocab) on the synthetic motif
dataset.

Run:  PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 300
"""
import argparse

from repro.configs import get_arch, reduced
from repro.launch.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.model if args.full else reduced(spec.model)
    cfg = cfg.replace(max_seq=max(cfg.max_seq, 128))
    tr = Trainer(cfg, optimizer=spec.optimizer, seq_len=128, global_batch=8,
                 ckpt_dir=args.ckpt_dir, peak_lr=3e-3)
    if tr.maybe_restore():
        print(f"resumed from step {tr.step}")
    hist = tr.train(args.steps, log_every=25)
    start, end = hist["loss"][0], hist["loss"][-1]
    print(f"\nloss {start:.3f} -> {end:.3f} over {args.steps} steps "
          f"({'LEARNING' if end < start - 0.3 else 'check config'})")


if __name__ == "__main__":
    main()
