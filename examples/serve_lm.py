"""End-to-end driver #3: autoregressive LM decode through the COMPILED
serving stack.

Earlier revisions of this example drove the eager jax ``ServeEngine``;
it now serves the quantized decoder (``models/vta_decoder``) through the
compiled path end to end — the same program/compiler/pool machinery the
rest of the repo benchmarks:

  * every linear is an int8 accelerator matmul (weights staged once as
    graph constants), attention is a host segment, and the KV caches
    live in **persistent** DRAM buffers at stable addresses;
  * one compiled program is one decode STEP, and each concurrent
    dialogue is one ``DevicePool`` session — the scheduler swaps each
    session's KV bytes in and out of its slot and gangs same-step
    accelerator segments across slots;
  * decode is fully autoregressive: the next embedding is chosen by
    greedy argmax over the program's own logits, so one wrong byte
    anywhere derails the whole token sequence — the final check is that
    every pooled dialogue reproduces the eager numpy reference's tokens
    exactly.

Run:  PYTHONPATH=src python examples/serve_lm.py --sessions 4 --steps 24
"""
import argparse
import time

import numpy as np

from repro.core.serve import DevicePool
from repro.models.vta_decoder import DecoderConfig, QuantDecoder


def greedy_decode_reference(dec: QuantDecoder, prompt_tok: int,
                            steps: int) -> list:
    """Eager numpy oracle: one dialogue, greedy argmax feedback."""
    ref = dec.reference()
    tok, out = prompt_tok, []
    for _ in range(steps):
        logits = ref.step(dec.token(tok))
        tok = int(np.argmax(logits))
        out.append(tok)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--pool", type=int, default=2)
    ap.add_argument("--blocks", type=int, default=2)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "simulator"])
    args = ap.parse_args()

    cfg = DecoderConfig(n_blocks=args.blocks,
                        s_max=max(96, args.steps + 8))
    dec = QuantDecoder(cfg)
    compiled = dec.compile()
    print(f"decoder: {cfg.n_blocks} blocks, d={cfg.d_model}, "
          f"vocab={cfg.vocab}, {compiled.persistent_bytes} persistent "
          f"B/session (KV caches at stable DRAM addresses)")

    prompts = [7 * i + 3 for i in range(args.sessions)]
    want = [greedy_decode_reference(dec, p, args.steps) for p in prompts]

    with DevicePool(compiled, size=args.pool, backend=args.backend) as pool:
        sess = [pool.session() for _ in range(args.sessions)]
        toks = list(prompts)
        decoded = [[] for _ in range(args.sessions)]
        t0 = time.perf_counter()
        for _ in range(args.steps):
            # lockstep round: same-step sessions gang their accel segments
            futs = [s.submit(x=dec.token(t)) for s, t in zip(sess, toks)]
            for i, fut in enumerate(futs):
                nxt = int(np.argmax(fut.wait(timeout=300)))
                decoded[i].append(nxt)
                toks[i] = nxt
        dt = time.perf_counter() - t0
        gangs = sum(s.ganged_steps for s in pool.slot_stats())
        print(f"served {args.sessions} dialogues x {args.steps} greedy "
              f"steps on {len(pool)} slots in {dt:.2f}s "
              f"({args.sessions * args.steps / dt:.1f} steps/s agg, "
              f"{gangs} ganged segments)")
        print("\n".join(pool.describe().splitlines()[1:]))

    for i, (got, ref) in enumerate(zip(decoded, want)):
        assert got == ref, (f"dialogue {i} diverged from the eager "
                            f"reference: {got} vs {ref}")
    print("all pooled dialogues reproduce the eager numpy reference's "
          "greedy tokens exactly:")
    for i, seq in enumerate(decoded):
        print(f"  dialogue {i} (prompt {prompts[i]:>3}): "
              + " ".join(f"{t:>2}" for t in seq))


if __name__ == "__main__":
    main()
