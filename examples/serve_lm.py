"""End-to-end driver #3: batched serving with the VTA int8 path.

Runs the continuous-batching engine twice — float weights, then int8 PTQ
weights through the VTA GEMM semantics — and compares outputs: the
quantized deployment (the paper's §5 pipeline, lifted to LMs) should
produce near-identical greedy decodes.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-3b
"""
import argparse

import numpy as np

from repro.configs import get_arch, reduced
from repro.launch.serve import Request, ServeEngine
from repro.models import transformer as T
from repro.models.quantized import quantize_params

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch).model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(args.requests)]

    results = {}
    for mode, p in (("float", params),
                    ("vta_int8", quantize_params(params))):
        engine = ServeEngine(cfg, p, batch_slots=4)
        reqs = [Request(rid=i, prompt=pr, max_new=args.max_new)
                for i, pr in enumerate(prompts)]
        done = engine.run(reqs)
        results[mode] = {r.rid: r.out_tokens for r in done}
        print(f"{mode}: served {len(done)} requests")

    agree = 0
    total = 0
    for rid in results["float"]:
        a, b = results["float"][rid], results["vta_int8"][rid]
        agree += sum(x == y for x, y in zip(a, b))
        total += len(a)
    print(f"int8 vs float greedy-token agreement: {agree}/{total} "
          f"({agree / total:.0%}) — the PTQ deployment preserves decodes")


if __name__ == "__main__":
    main()
