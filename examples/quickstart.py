"""Quickstart: the full VTA stack in ~100 lines.

1. Quantize a float matmul workload to int8 (the paper's PTQ step).
2. Lower it with the scheduler (tensorization + virtual threading).
3. JIT the VTA instruction stream with the runtime.
4. Execute on the behavioral simulator; cross-check against numpy.
5. Time it with the cycle-level pipeline model, with and without
   virtual threading — the paper's latency-hiding result in miniature.
6. Route the *same* encoded stream through the second engine
   (PallasBackend) and differentially check it against the simulator —
   the paper's heterogeneous-execution story (§3).
7. Compile a whole multi-op graph (two chained matmuls + requant) into
   ONE task-ISA stream with the program-level JIT, then rerun it on new
   data without re-scheduling — the paper's module-level JIT-cost
   amortization.
8. Run a *general* kh*kw>1 convolution (a ResNet C2-style 3x3) through
   the same stack: the direct-conv schedule's per-output-row GEMMs are
   coalesced into batched Pallas calls, so the layer takes ZERO eager
   fallback iterations — verified by the fast-path counters — and the
   lowering decision (direct vs im2col vs via_matmul) is inspectable on
   the compiled program.
9. Serve the compiled program: compile ONCE, call N times.  Dependent
   layers are joined by buffer-granular fences (only the consumer's
   loads of the produced buffer wait on the producer's final store —
   inspect the fence edges in describe()), weights are graph constants
   staged into DRAM at compile time, intermediates live in a recycled
   arena, and the encoded stream is pre-staged — so every repeat call
   performs ZERO DRAM allocation (asserted) and stages only the fresh
   activations.
10. Pool-serve it asynchronously: clone the staged device onto a
   DevicePool, submit() a burst of requests, wait() the futures out of
   order.  Requests parked at the same segment execute as one lockstep
   gang — every Pallas launch carries all gang members' tiles — and
   each slot keeps the zero-allocation serving contract independently
   (trimmed clones make a stray alloc an ERROR).  Per-slot stats show
   the sharding.
11. Decode a transformer through the same stack: a 2-block quantized
   decoder whose KV caches live in *persistent* DRAM buffers — the
   third liveness class next to constants and arena intermediates.
   One compiled program is one decode STEP; four pool sessions hold
   four independent dialogues, the scheduler swaps each session's KV
   bytes at stable addresses, and every step is bit-exact against the
   eager numpy reference with zero per-step DRAM allocation.
12. Continuous-batch a 2-program mix: co-stage two different graphs
   into ONE resident DRAM image (compile_multi — disjoint ranges, every
   baked address valid), serve both through one pool behind an
   admission window (core.sched): requests park up to window_us, same-
   program arrivals release together as full-width gangs, programs
   never mix in a gang, and backpressure is typed — then dump the whole
   control plane with describe().
13. Shrink the weights below a byte: the same linear layer at bits=4
   stores its weight constant int4-PACKED in the DRAM image (half the
   staged bytes — describe() shows it), both engines decode the packed
   stream bit-exactly, decode-shaped calls auto-route to the T-MAC-style
   LUT-GEMM kernel, and the int4 output tracks the int8 path's dequant
   reference within the coarser quantization step.
14. Kill a serving slot mid-dialogue and watch the pool heal itself:
   the slot respawns from the pristine staged image (max_respawns), the
   decode session transparently restores its KV bytes from the last
   checkpoint (checkpoint_every=1 — restored_from_step is visible,
   never silent), the dialogue continues bit-exact against the same
   eager reference, and describe() carries the death/respawn/restore
   accounting.
15. Autotune the deployment (paper §4): a seeded design-space search
   prices candidate template geometries + schedule knobs on the
   calibrated cycle oracle, measures and byte-validates only the top
   predictions, and writes the winner into the tuning cache — so
   recompiling the same op under the tuned spec is all cache HITS
   (describe() shows the hit/miss counters and the chosen conv
   lowering, which is itself picked by replayed cycles, not a rule).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Program, hwspec, quantize as q
from repro.core.backend import CrossBackendChecker, assert_fast_path
from repro.core.conv import ConvShape, conv2d_reference
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, matmul_reference,
                                  read_matmul_result, schedule_matmul)
from repro.core.simulator import TimingModel


def main() -> None:
    spec = hwspec.pynq()
    print(f"VTA template: {spec.batch}x{spec.block_in}x{spec.block_out} "
          f"GEMM core @ {spec.freq_mhz:.0f} MHz "
          f"= {spec.peak_gops:.1f} GOPS peak")

    # --- 1. float workload -> int8 (post-training quantization, §5) ---
    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 512)).astype(np.float32)
    w = rng.normal(size=(256, 512)).astype(np.float32) / np.sqrt(512)
    qx, qw = q.calibrate(x), q.calibrate(w)
    qy = q.calibrate(x @ w.T)
    shift = q.choose_requant_shift(qx.scale, qw.scale, qy.scale)
    xq, wq = q.quantize(x, qx), q.quantize(w, qw)

    # --- 2-4. schedule, JIT, simulate, verify ---
    rt = Runtime(spec)
    plan = schedule_matmul(rt, xq, wq, epilogue=Epilogue(shift=shift),
                           virtual_threads=2)
    stats = rt.synchronize()
    got = read_matmul_result(rt, plan)
    want = matmul_reference(xq, wq, epilogue=Epilogue(shift=shift))
    assert np.array_equal(got, want), "simulator diverged from oracle!"
    print(f"exact int8 result ok; {stats.gemm_macs / 1e6:.1f} M MACs, "
          f"{stats.dram_rd_bytes / 1e3:.0f} kB read")

    # --- 5. latency hiding (Fig. 4 / Fig. 15) ---
    for vt in (1, 2):
        rt = Runtime(spec)
        schedule_matmul(rt, xq, wq, virtual_threads=vt)
        s = rt.synchronize(timing=TimingModel(spec))
        print(f"virtual_threads={vt}: {s.total_cycles:,} cycles, "
              f"compute utilization {s.compute_utilization:.1%}, "
              f"{s.gops(spec.freq_mhz):.1f} GOPS")

    # --- 6. heterogeneous execution: one stream, two engines (§3) ---
    rt = Runtime(spec)
    plan = schedule_matmul(rt, xq, wq, epilogue=Epilogue(shift=shift),
                           virtual_threads=2)
    report = CrossBackendChecker().check_runtime(rt)
    got = read_matmul_result(rt, plan)
    assert report.matches, "engines diverged!"
    assert np.array_equal(got, want), "adopted image diverged from oracle!"
    print("cross-backend check ok: "
          + ", ".join(f"{r.backend} {r.stats.wall_time_s * 1e3:.0f} ms"
                      for r in report.runs)
          + "  (pallas time includes one-time jit compile; see "
            "benchmarks/bench_kernels.py for warmed steady-state)")

    # --- 7. program-level JIT: a whole graph in ONE stream ---
    w2 = rng.normal(size=(128, 256)).astype(np.float32) / np.sqrt(256)
    w2q = q.quantize(w2, q.calibrate(w2))
    ep1 = Epilogue(shift=shift, relu=True)
    ep2 = Epilogue(shift=6)
    prog = Program(spec)
    h = prog.matmul(prog.input("x", xq.shape), prog.input("w1", wq.shape),
                    epilogue=ep1)
    prog.matmul(h, prog.input("w2", w2q.shape), epilogue=ep2)
    compiled = prog.compile()
    print(f"program: {compiled.describe()}")
    want2 = matmul_reference(matmul_reference(xq, wq, ep1), w2q, ep2)
    for backend in ("simulator", "pallas"):
        out = compiled(backend=backend, x=xq, w1=wq, w2=w2q)
        assert np.array_equal(out, want2), f"{backend} diverged!"
    # rerun with fresh activations: rebinds DRAM, no re-scheduling
    from repro.core import program as program_mod
    builds = program_mod.STREAM_BUILDS
    x2 = q.quantize(rng.normal(size=xq.shape).astype(np.float32), qx)
    out = compiled(x=x2, w1=wq, w2=w2q)
    assert program_mod.STREAM_BUILDS == builds
    assert np.array_equal(
        out, matmul_reference(matmul_reference(x2, wq, ep1), w2q, ep2))
    print("program JIT ok: 2-op graph, one stream, both engines exact; "
          "second call hit the stream cache")

    # --- 8. general conv2d on the Pallas fast path (kh*kw > 1) ---
    shape = ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                      stride=1, pad=1)                  # C2-style 3x3
    xq3 = rng.integers(-64, 64, size=(1, 32, 14, 14), dtype=np.int8)
    k3 = rng.integers(-16, 16, size=(32, 32, 3, 3), dtype=np.int8)
    ep3 = Epilogue(shift=5, relu=True)
    cprog = Program(spec)
    cprog.conv2d(cprog.input("x", xq3.shape), cprog.input("k", k3.shape),
                 shape, epilogue=ep3, name="c2")
    cc = cprog.compile()
    print(f"conv program: {cc.describe()}")            # shows c2:direct
    want3 = conv2d_reference(xq3, k3, shape, epilogue=ep3)
    for backend in ("simulator", "pallas"):
        out3 = cc(backend=backend, x=xq3, k=k3)
        assert np.array_equal(out3, want3), f"{backend} conv diverged!"
    assert_fast_path(cc.last_stats)                    # zero eager GEMMs
    eager = sum(s.eager_gemm_insns for s in cc.last_stats)
    coal = sum(s.coalesced_gemm_insns for s in cc.last_stats)
    print(f"3x3 conv ok on the fast path: {coal} GEMM insns coalesced "
          f"into batched Pallas calls, {eager} eager fallbacks")

    # --- 9. serve it: compile once, call N times, zero per-call DRAM ---
    import time
    sprog = Program(spec)
    t = sprog.conv2d(sprog.input("x", xq3.shape),
                     sprog.constant("k1", k3),      # weight staged ONCE
                     shape, epilogue=ep3, name="s1")
    sprog.conv2d(t, sprog.constant("k2",
                                   rng.integers(-16, 16, size=(32, 32, 1, 1),
                                                dtype=np.int8)),
                 ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=1, kw=1,
                           stride=1, pad=0),
                 epilogue=ep3, name="s2")
    served = sprog.compile()
    print(f"serving program: {served.describe()}")    # fence edge + arena
    served(backend="pallas", x=xq3)                   # warm jit caches
    n_calls = 16
    dram_mark = served.device.dram._next
    t0 = time.perf_counter()
    for _ in range(n_calls):
        out9 = served(backend="pallas", x=xq3)
    dt = time.perf_counter() - t0
    assert served.device.dram._next == dram_mark, \
        "serving loop grew the DRAM image!"
    stats9 = served.last_stats[0]
    print(f"served {n_calls} calls at {n_calls / dt:.1f} calls/s: "
          f"{stats9.n_buffer_fences} fence / {stats9.n_join_barriers} "
          f"barriers per stream, {served.last_staging_bytes} B staged per "
          f"call (activations only), DRAM image constant, "
          f"{sum(s.tiles_resolved for s in served.last_stats)} tiles in "
          f"{sum(s.tile_batches for s in served.last_stats)} batched "
          f"launches")

    # --- 10. pool-serve it: async submit/wait over cloned devices ---
    from repro.core.serve import DevicePool
    with DevicePool(served, size=2, backend="pallas",
                    policy="least_loaded") as pool:
        xs = [rng.integers(-64, 64, size=xq3.shape, dtype=np.int8)
              for _ in range(8)]
        futs = [pool.submit(x=xi) for xi in xs]        # async burst
        marks = [s.device.dram._next for s in pool.slots]
        for fut, xi in reversed(list(zip(futs, xs))):  # wait out of order
            got = fut.wait(timeout=600)
            want = served(x=xi)                        # serial oracle
            assert np.array_equal(got, want), "pooled result diverged!"
        assert [s.device.dram._next for s in pool.slots] == marks, \
            "a pool slot grew its DRAM image!"
        gangs = sum(s.ganged_steps for s in pool.slot_stats())
        print(f"pool-served {len(xs)} async requests on "
              f"{len(pool)} slots ({gangs} ganged segments, byte-exact "
              f"vs serial, per-slot DRAM constant):")
        print("\n".join(pool.describe().splitlines()[1:]))  # per-slot

    # --- 11. persistent state: KV-cache decode through the pool ---
    from repro.models.vta_decoder import QuantDecoder
    dec = QuantDecoder()                       # 2 blocks, d=64, numpy attn
    cdec = dec.compile()
    print(f"decoder program: {cdec.describe().splitlines()[0]}")
    n_steps = 8
    with DevicePool(cdec, size=2, backend="pallas") as dpool:
        sess = [dpool.session() for _ in range(4)]   # 4 dialogues
        refs = [dec.reference() for _ in range(4)]
        for t in range(n_steps):                     # lockstep decode
            xs = [dec.token(1000 * i + t) for i in range(4)]
            futs = [s.submit(x=xi) for s, xi in zip(sess, xs)]
            for fut, ref, xi in zip(futs, refs, xs):
                assert np.array_equal(fut.wait(300), ref.step(xi)), \
                    "pooled decode diverged from the eager reference!"
        # each session's KV cache really holds ITS dialogue, in place
        for i, s in enumerate(sess):
            assert np.array_equal(s.state("k0"), refs[i].K[0])
            assert int(s.state("pos0")[0]) == n_steps
        print(f"decoded {n_steps} steps x {len(sess)} sessions "
              f"({cdec.persistent_bytes} persistent B/session at stable "
              f"addresses), bit-exact vs eager numpy; per-slot state:")
        print("\n".join(dpool.describe().splitlines()[1:]))

    # --- 12. continuous batching: 2-program mix behind an admission
    #         window ---
    from repro.core.program import compile_multi
    from repro.core.sched import SchedConfig, Scheduler

    ws = rng.integers(-64, 64, size=(64, 64), dtype=np.int8)
    pa = Program(spec)
    ta = pa.input("x", (16, 64))
    pa.output(pa.matmul(ta, pa.constant("wa", ws), epilogue=ep2))
    pb = Program(spec)
    tb = pb.input("x", (16, 64))
    tb = pb.matmul(tb, pb.constant("wb", ws), epilogue=ep2)
    pb.output(pb.matmul(tb, pb.constant("wb2", ws.T.copy()),
                        epilogue=ep2))
    ca, cb = compile_multi([pa, pb])     # ONE image, disjoint ranges
    assert not ca.image_range.overlaps(cb.image_range)
    with DevicePool([ca, cb], size=4, backend="pallas") as mpool:
        sched = Scheduler(mpool, SchedConfig(window_us=1500.0))
        feeds = [rng.integers(-64, 64, size=(16, 64), dtype=np.int8)
                 for _ in range(8)]
        futs = [sched.submit(program=i % 2, x=f)
                for i, f in enumerate(feeds)]
        for i, (fut, xf) in enumerate(zip(futs, feeds)):
            want = matmul_reference(xf, ws, ep2)
            if i % 2:
                want = matmul_reference(want, ws.T.copy(), ep2)
            assert np.array_equal(fut.wait(timeout=600), want), \
                "windowed result diverged from serial!"
        sa, sb = sched.stats()
        print(f"continuous-batched {sa.completed}+{sb.completed} "
              f"requests of 2 co-staged programs "
              f"({sa.releases + sb.releases} releases, max gang "
              f"{max(sa.max_gang, sb.max_gang)}, programs never mixed "
              f"in a gang); control plane:")
        print(sched.describe())
        sched.close()

    # --- 13. sub-byte weights: int4 packed storage + LUT-GEMM decode ---
    from repro.core.backend import PallasBackend, SimulatorBackend
    from repro.models.quantized import VtaLinear

    wf = rng.normal(size=(96, 64)).astype(np.float32) * 0.1
    xf = rng.normal(size=(2, 96)).astype(np.float32)   # decode-shaped
    lin8, lin4 = VtaLinear(wf, bits=8), VtaLinear(wf, bits=4)
    y8, y4 = lin8(xf), lin4(xf)
    # the packed program is bit-exact across both engines...
    assert np.array_equal(lin4(xf, backend=PallasBackend()),
                          lin4(xf, backend=SimulatorBackend()))
    c8 = next(iter(lin8._programs.values()))
    c4 = next(iter(lin4._programs.values()))
    assert c4.const_bytes * 2 == c8.const_bytes       # int4 = half the bytes
    # ...and decode-shaped calls route through the LUT-GEMM kernel
    lin4(xf, backend=PallasBackend())
    luts = sum(s.lut_launches for s in c4.last_stats)
    # int4 output tracks the int8 path within the coarser quant step
    q_step = float(np.abs(y4 - xf @ wf).max())
    print(f"int4 VtaLinear: {c4.describe().splitlines()[0]}")
    print(f"  const {c4.const_bytes}B packed vs {c8.const_bytes}B int8, "
          f"{luts} LUT-GEMM launches, |y4 - x@W|max {q_step:.3f} "
          f"(int8 path {np.abs(y8 - xf @ wf).max():.3f})")

    # --- 14. self-healing: kill a slot mid-dialogue, respawn + restore ---
    with DevicePool(cdec, size=2, backend="pallas", max_respawns=2,
                    checkpoint_every=1) as hpool:
        hsess = hpool.session(slot=0)
        href = dec.reference()
        for t in range(4):
            xi = dec.token(t)
            assert np.array_equal(hsess.submit(x=xi).wait(300),
                                  href.step(xi)), "decode diverged!"
        hpool.kill_slot(0)                   # chaos: the slot dies NOW
        st = hpool.slot_stats()[0]
        assert st.deaths == 1 and st.respawns == 1, \
            "slot did not respawn from the pristine image!"
        assert hsess.stats.restored_from_step == 4, \
            "session did not restore from its checkpoint!"
        for t in range(4, 6):                # the dialogue just continues
            xi = dec.token(t)
            assert np.array_equal(hsess.submit(x=xi).wait(300),
                                  href.step(xi)), \
                "restored decode diverged from the eager reference!"
        print(f"self-healed mid-dialogue: slot 0 died and respawned, "
              f"session restored from step "
              f"{hsess.stats.restored_from_step} (checkpoint_every=1), "
              f"decode continued bit-exact; recovery accounting:")
        print("\n".join(hpool.describe().splitlines()[1:]))

    # --- 15. autotune the deployment, then compile out of the cache ---
    from repro.core import autotune

    wl = autotune.conv_workload(
        ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                  stride=1, pad=1), seed=0)
    res = autotune.search(wl, seed=0, n_candidates=8, top_n=2, repeats=1)
    assert res.winner is not None and res.winner.validated
    # rebuild the workload under the winning spec: every accel op now
    # resolves from the tuning record the search just wrote
    tuned_prog, feeds, refs = wl.build(res.winner.candidate.spec,
                                       res.winner.candidate.virtual_threads,
                                       res.winner.candidate.lowering)
    tuned = tuned_prog.compile(use_cache=False)
    assert tuned.tune_hits >= 1 and tuned.tune_misses == 0, \
        "recompile under the tuned spec must be all cache hits!"
    assert np.array_equal(tuned(backend="simulator", **feeds), refs["y"])
    lowering = next(n.lowering for n in tuned.nodes if n.op == "conv2d")
    print(f"autotuned {wl.name}: winner {res.winner.candidate.label()} "
          f"({res.speedup_measured:.2f}x measured over the default), "
          f"conv lowering '{lowering}' picked by replayed cycles")
    print(f"  recompile: {tuned.describe().splitlines()[-1]}")


if __name__ == "__main__":
    main()
