"""Generate the §Dry-run / §Roofline sections of EXPERIMENTS.md from
experiments/dryrun/*.json.  Run after `python -m repro.launch.dryrun --all`.
"""
from __future__ import annotations

import glob
import json
import os
import sys

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(dirname="experiments/dryrun"):
    cells = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        d["_file"] = os.path.basename(f)
        cells.append(d)
    cells.sort(key=lambda d: (d["arch"], SHAPE_ORDER.get(d["shape"], 9),
                              d["mesh"], d.get("quantized", False),
                              d["_file"]))
    return cells


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_row(d):
    r = d["roofline"]
    tag = ""
    if d.get("quantized"):
        tag = " int8"
    base = d["_file"]
    if base.count("__") > 2 and "int8" not in base:
        tag += " [" + base.split("__", 3)[-1].replace(".json", "") + "]"
    dom_t = max(r["compute_term_s"], r["memory_term_s"],
                r["collective_term_s"])
    frac = r["compute_term_s"] / dom_t if dom_t > 0 else 0.0
    return ("| {arch} | {shape}{tag} | {mesh} | {c:.1f} | {m:.1f} | {l:.1f} "
            "| {dom} | {frac:.2f} | {useful:.2f} | {gib} |").format(
        arch=d["arch"], shape=d["shape"], tag=tag,
        mesh="2x16x16" if "multi" in d["mesh"] else "16x16",
        c=r["compute_term_s"] * 1e3, m=r["memory_term_s"] * 1e3,
        l=r["collective_term_s"] * 1e3, dom=r["dominant"][:4],
        frac=frac, useful=r["useful_flops_ratio"],
        gib=fmt_bytes(d["memory"].get("total_bytes_per_device", 0)))


def main():
    cells = load()
    baseline = [d for d in cells
                if not d.get("quantized") and not d.get("overrides")
                and d["_file"].count("__") == 2]
    print(f"<!-- generated from {len(cells)} cell JSONs -->")
    print()
    print("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| dom | comp/dom | useful | GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in baseline:
        print(roofline_row(d))
    extras = [d for d in cells if d not in baseline]
    if extras:
        print("\n**Variant cells (int8 / perf-loop overrides):**\n")
        print("| arch | shape | mesh | compute ms | memory ms | collective ms "
              "| dom | comp/dom | useful | GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for d in extras:
            print(roofline_row(d))


if __name__ == "__main__":
    main()
