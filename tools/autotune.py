"""Design-space autotuner CLI — the one DSE entry point in tools/.

Runs the two-stage seeded search of ``repro.core.autotune`` (calibrated
TimingModel replay as the cheap oracle over every candidate, measured
wall time + cross-engine byte validation for the top-N) over a conv
and/or matmul workload, prints the trajectory, diffs the winner against
a stored baseline JSON (the old hillclimb-style report), and persists
the winning decisions into a TuningCache file that ``Program.compile``
auto-loads via ``REPRO_TUNE_CACHE``.

Usage:
  PYTHONPATH=src python tools/autotune.py conv --seed 0 --candidates 24
  PYTHONPATH=src python tools/autotune.py matmul --m 128 --k 256 --n 256
  PYTHONPATH=src python tools/autotune.py both \\
      --cache tuning_cache.json --baseline benchmarks/BENCH_autotune.json
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import autotune, hwspec                     # noqa: E402
from repro.core.conv import ConvShape                       # noqa: E402


def _diff_vs_baseline(result_json: dict, baseline_path: str) -> None:
    """Hillclimb-style report: percent deltas of the winner's predicted
    cycles and measured wall against the stored trajectory JSON."""
    if not os.path.exists(baseline_path):
        print(f"(no baseline at {baseline_path} — skipping diff)")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    base_by_name = {w["workload"]: w for w in base.get("workloads", [])}
    print("\n=== delta vs baseline ===")
    for w in result_json["workloads"]:
        b = base_by_name.get(w["workload"])
        if b is None or b.get("winner") is None or w["winner"] is None:
            print(f"{w['workload']:24s}: no comparable baseline winner")
            continue
        for k, scale, unit in (("predicted_cycles", 1, "cyc"),
                               ("measured_s", 1e3, "ms")):
            bv, cv = b["winner"].get(k), w["winner"].get(k)
            if not bv or not cv:
                continue
            pct = (cv - bv) / bv * 100
            print(f"{w['workload']:24s} {k:16s}: {bv * scale:10.2f} -> "
                  f"{cv * scale:10.2f} {unit}  ({pct:+.1f}%)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("workload", choices=("conv", "matmul", "both"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--candidates", type=int, default=24,
                    help="sampled design points (oracle stage)")
    ap.add_argument("--top", type=int, default=4,
                    help="candidates measured + validated (stage 2)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--conv-hw", type=int, default=14,
                    help="conv spatial size (H=W)")
    ap.add_argument("--conv-c", type=int, default=32,
                    help="conv channels (ic=oc)")
    ap.add_argument("--conv-khw", type=int, default=3,
                    help="conv kernel size (kh=kw), stride 1, same pad")
    ap.add_argument("--spec", choices=("pynq", "calibrated"),
                    default="calibrated",
                    help="base template instance to search around")
    ap.add_argument("--cache", default=None,
                    help="TuningCache JSON to merge winners into "
                         "(load+save; point REPRO_TUNE_CACHE here)")
    ap.add_argument("--baseline", default=None,
                    help="stored trajectory JSON to diff the winner "
                         "against (e.g. benchmarks/BENCH_autotune.json)")
    ap.add_argument("--out", default=None,
                    help="write this run's trajectory JSON here")
    args = ap.parse_args(argv)

    base_spec = (hwspec.calibrated() if args.spec == "calibrated"
                 else hwspec.pynq())
    cache = autotune.global_cache()
    if args.cache and os.path.exists(args.cache):
        print(f"loaded {cache.load(args.cache)} record(s) from "
              f"{args.cache}")

    workloads = []
    if args.workload in ("conv", "both"):
        khw, hw, c = args.conv_khw, args.conv_hw, args.conv_c
        workloads.append(autotune.conv_workload(
            ConvShape(n=1, h=hw, w=hw, ic=c, oc=c, kh=khw, kw=khw,
                      stride=1, pad=khw // 2), seed=args.seed))
    if args.workload in ("matmul", "both"):
        workloads.append(autotune.matmul_workload(
            args.m, args.k, args.n, seed=args.seed))

    out = {"seed": args.seed, "base_spec": autotune.spec_key(base_spec),
           "workloads": []}
    for wl in workloads:
        res = autotune.search(wl, base_spec=base_spec, seed=args.seed,
                              n_candidates=args.candidates,
                              top_n=args.top, repeats=args.repeats,
                              cache=cache, log=print)
        out["workloads"].append(res.to_json())
        if res.winner is not None:
            cfg = res.sched_config()
            print(f"  serving knobs: gang_width={cfg.gang_width} "
                  f"window_us={cfg.window_us:.0f}")

    if args.cache:
        cache.save(args.cache)
        print(f"saved {len(cache)} record(s) to {args.cache}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"trajectory written to {args.out}")
    if args.baseline:
        _diff_vs_baseline(out, args.baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
