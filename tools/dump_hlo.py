"""Dump compiled HLO for one cell (debug tool for the perf loop)."""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.launch.dryrun as DR
from repro.launch.dryrun import *

arch, shape_name, multi = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
out = sys.argv[4]
overrides = json.loads(sys.argv[5]) if len(sys.argv) > 5 else None
quant = len(sys.argv) > 6 and sys.argv[6] == "int8"

spec = get_arch(arch); shape = SHAPES[shape_name]
mesh = make_production_mesh(multi_pod=multi)
sc = DR._sharding_config(mesh, dp_over_model=getattr(spec, "dp_over_model", False))
cfg = for_shape(spec, shape, sharding=sc, quantized=quant)
if overrides: cfg = cfg.replace(**overrides)
with meshctx.use_mesh(mesh):
    params_shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(params_shapes, cfg, mesh, fsdp=spec.fsdp)
    p_shard = named_shardings(p_specs, mesh)
    batch_sds = input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())
    if shape.kind == "train":
        opt_init, train_step = build_train_step(cfg, spec.optimizer)
        opt_shapes = jax.eval_shape(opt_init, params_shapes)
        o_specs = opt_state_specs(opt_shapes, p_specs, params_shapes)
        o_shard = named_shardings(o_specs, mesh)
        b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs(batch_sds, cfg, mesh).items()}
        jitted = jax.jit(train_step, in_shardings=(p_shard, o_shard, b_shard, repl),
                         out_shardings=(p_shard, o_shard, repl), donate_argnums=(0,1))
        comp = jitted.lower(params_shapes, opt_shapes, batch_sds,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    elif shape.kind == "prefill":
        caches_shapes = jax.eval_shape(lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
        c_shard = named_shardings(cache_specs(caches_shapes, cfg, mesh), mesh)
        if quant:
            from repro.models.quantized import quantized_param_shapes
            params_shapes = quantized_param_shapes(params_shapes)
            p_shard = named_shardings(param_specs(params_shapes, cfg, mesh, fsdp=spec.fsdp), mesh)
        b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs(batch_sds, cfg, mesh).items()}
        jitted = jax.jit(lambda p, b, c: T.prefill(p, cfg, b, c),
                         in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(repl, c_shard), donate_argnums=(2,))
        comp = jitted.lower(params_shapes, batch_sds, caches_shapes).compile()
    else:
        caches_shapes = jax.eval_shape(lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
        c_shard = named_shardings(cache_specs(caches_shapes, cfg, mesh), mesh)
        if quant:
            from repro.models.quantized import quantized_param_shapes
            params_shapes = quantized_param_shapes(params_shapes)
            p_shard = named_shardings(param_specs(params_shapes, cfg, mesh, fsdp=spec.fsdp), mesh)
        tok_spec = batch_specs({"token": batch_sds["token"]}, cfg, mesh)["token"]
        jitted = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos),
                         in_shardings=(p_shard, c_shard, NamedSharding(mesh, tok_spec), repl),
                         out_shardings=(repl, c_shard), donate_argnums=(1,))
        comp = jitted.lower(params_shapes, caches_shapes, batch_sds["token"], batch_sds["pos"]).compile()
open(out, "w").write(comp.as_text())
print("wrote", out)
