"""Perf hillclimbing driver: run a cell with config overrides and diff the
roofline terms against the stored baseline JSON.

Usage:
  PYTHONPATH=src python tools/hillclimb.py kimi-k2-1t-a32b train_4k multi \\
      '{"moe_combine": "reduce_scatter", "seq_parallel_residual": true}' tag1
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

from repro.launch.dryrun import run_cell


def main():
    arch, shape, mesh = sys.argv[1], sys.argv[2], sys.argv[3]
    overrides = json.loads(sys.argv[4]) if len(sys.argv) > 4 and sys.argv[4] else None
    tag = sys.argv[5] if len(sys.argv) > 5 else "opt"
    quant = len(sys.argv) > 6 and sys.argv[6] == "int8"
    multi = mesh == "multi"

    base_f = f"experiments/dryrun/{arch}__{shape}__{mesh}.json"
    base = json.load(open(base_f)) if os.path.exists(base_f) else None

    cell = run_cell(arch, shape, multi, quantized=quant, overrides=overrides)
    out = f"experiments/dryrun/{arch}__{shape}__{mesh}__{tag}.json"
    with open(out, "w") as f:
        json.dump(cell, f, indent=1)

    if base:
        br, cr = base["roofline"], cell["roofline"]
        bm = base["memory"].get("total_bytes_per_device", 0) / 2**30
        cm = cell["memory"].get("total_bytes_per_device", 0) / 2**30
        print("\n=== delta vs baseline ===")
        for k in ("compute_term_s", "memory_term_s", "collective_term_s"):
            b, c = br[k], cr[k]
            pct = (c - b) / b * 100 if b else float("nan")
            print(f"{k:20s}: {b*1e3:10.1f} -> {c*1e3:10.1f} ms  ({pct:+.1f}%)")
        print(f"{'useful_ratio':20s}: {br['useful_flops_ratio']:.3f} -> "
              f"{cr['useful_flops_ratio']:.3f}")
        print(f"{'GiB/device':20s}: {bm:.2f} -> {cm:.2f}")
        print(f"{'dominant':20s}: {br['dominant']} -> {cr['dominant']}")


if __name__ == "__main__":
    main()
