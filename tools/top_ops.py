"""Rank memory/collective/flops contributors in a dumped HLO file."""
import sys, re
from repro.launch.hlo_analysis import parse_hlo, _shape_bytes, _trip_count, analyze
txt = open(sys.argv[1]).read()
kind = sys.argv[2] if len(sys.argv) > 2 else "coll"
comps, entry = parse_hlo(txt)
recs = []
def walk(cname, mult):
    comp = comps.get(cname)
    if comp is None: return
    for op in comp.ops:
        base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
        if kind == "coll" and base in ("all-reduce","all-gather","reduce-scatter","all-to-all","collective-permute"):
            recs.append((_shape_bytes(op.result_type)*mult, mult, cname[:30], base, op.result_type[:70], op.line.strip()[:180]))
        if kind == "dot" and base == "dot":
            recs.append((_shape_bytes(op.result_type)*mult, mult, cname[:30], base, op.result_type[:70], op.line.strip()[:160]))
        if op.opcode == "while":
            mb = re.search(r"body=%?([\w.\-]+)", op.line)
            mc = re.search(r"condition=%?([\w.\-]+)", op.line)
            trips = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
            if mb: walk(mb.group(1), mult*trips)
walk(entry, 1.0)
recs.sort(reverse=True)
for r in recs[:20]:
    print(f"{r[0]/1e9:9.3f} GB x{r[1]:5.0f} {r[2]:30s} {r[3]:18s} {r[4]}")
    if len(sys.argv) > 3: print("      ", r[5])
st = analyze(txt, 256)
print("\ncollective bytes:", {k: f"{v/1e9:.1f}GB" for k,v in st.collective_bytes.items()})
print("memory bytes:", f"{st.memory_bytes/1e12:.2f}TB", " dot flops:", f"{st.dot_flops/1e12:.1f}T")
