"""Program-level JIT vs per-op synchronize (the paper's §3 amortization).

Per-op execution pays one VTASynchronize round-trip — finalize, run to
FINISH, host read-back/re-pack — for every layer.  The program-level JIT
lowers the whole chain into one stream once, then every call just rebinds
DRAM and re-runs the encoded artifact.  This benchmark times an int8 MLP
chain both ways on both engines and reports the compile-once cost.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import Program, hwspec
from repro.core.backend import assert_fast_path
from repro.core.conv import (ConvShape, conv2d_reference, read_conv_result,
                             schedule_conv2d)
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, matmul_reference,
                                  read_matmul_result, schedule_matmul)


def _per_op(spec, x, weights, eps, backend):
    cur = x
    for w, ep in zip(weights, eps):
        rt = Runtime(spec)
        plan = schedule_matmul(rt, cur, w, epilogue=ep)
        rt.synchronize(backend=backend)
        cur = read_matmul_result(rt, plan)
    return cur


def run(m: int = 128, d: int = 256, layers: int = 3):
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=(m, d), dtype=np.int8)
    weights = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
               for _ in range(layers)]
    eps = [Epilogue(shift=7, relu=True)] * (layers - 1) + [Epilogue(shift=7)]

    ref = x
    for w, ep in zip(weights, eps):
        ref = matmul_reference(ref, w, ep)

    prog = Program(spec)
    t = prog.input("x", x.shape)
    for i, w in enumerate(weights):
        t = prog.matmul(t, prog.input(f"w{i}", w.shape), epilogue=eps[i])
    t0 = time.perf_counter()
    compiled = prog.compile(use_cache=False)
    compile_s = time.perf_counter() - t0
    feeds = {"x": x, **{f"w{i}": w for i, w in enumerate(weights)}}

    rows = []
    print(f"{layers}-layer int8 MLP, {m}x{d} @ {d}x{d}: "
          f"{compiled.insn_count} insns in one stream "
          f"(compile {compile_s * 1e3:.0f} ms)")
    print(f"{'engine':<10} {'per-op s':>10} {'program s':>10} {'speedup':>8}")
    for backend in ("simulator", "pallas"):
        compiled(backend=backend, **feeds)      # warm (jit, caches)
        t0 = time.perf_counter()
        got_po = _per_op(spec, x, weights, eps, backend)
        per_op_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        got_pr = compiled(backend=backend, **feeds)
        program_s = time.perf_counter() - t0
        assert np.array_equal(got_po, ref) and np.array_equal(got_pr, ref), \
            backend
        rows.append(dict(backend=backend, per_op_s=round(per_op_s, 4),
                         program_s=round(program_s, 4),
                         speedup_x=round(per_op_s / max(program_s, 1e-9), 2),
                         exact=True))
        print(f"{backend:<10} {per_op_s:>10.3f} {program_s:>10.3f} "
              f"{rows[-1]['speedup_x']:>7.2f}x")
    return dict(compile_s=round(compile_s, 4),
                insns=compiled.insn_count, rows=rows)


def run_conv(hw: int = 28, ch: int = 64):
    """Conv chain (3x3 direct -> 1x1 via GEMM) on PallasBackend: per-op
    sync vs one compiled Program, with the general-conv fast path proven
    by the eager counters (pre-PR, the 3x3 stage ran the eager loop)."""
    spec = hwspec.pynq()
    s1 = ConvShape(n=1, h=hw, w=hw, ic=ch, oc=ch, kh=3, kw=3,
                   stride=1, pad=1)
    s2 = ConvShape(n=1, h=hw, w=hw, ic=ch, oc=ch, kh=1, kw=1,
                   stride=1, pad=0)
    rng = np.random.default_rng(1)
    x = rng.integers(-64, 64, size=(1, ch, hw, hw), dtype=np.int8)
    k1 = rng.integers(-16, 16, size=(ch, ch, 3, 3), dtype=np.int8)
    k2 = rng.integers(-16, 16, size=(ch, ch, 1, 1), dtype=np.int8)
    ep = Epilogue(shift=6, relu=True)
    ref = conv2d_reference(conv2d_reference(x, k1, s1, epilogue=ep),
                           k2, s2, epilogue=ep)

    prog = Program(spec)
    t = prog.conv2d(prog.input("x", x.shape), prog.input("k1", k1.shape),
                    s1, epilogue=ep)
    prog.conv2d(t, prog.input("k2", k2.shape), s2, epilogue=ep)
    compiled = prog.compile(use_cache=False)
    feeds = dict(x=x, k1=k1, k2=k2)
    compiled(backend="pallas", **feeds)            # warm jit caches

    t0 = time.perf_counter()
    rt = Runtime(spec)
    p1 = schedule_conv2d(rt, x, k1, s1, epilogue=ep)
    rt.synchronize(backend="pallas")
    mid = read_conv_result(rt, p1)
    rt2 = Runtime(spec)
    p2 = schedule_conv2d(rt2, mid, k2, s2, epilogue=ep, via_matmul=True)
    rt2.synchronize(backend="pallas")
    got_po = read_conv_result(rt2, p2)
    per_op_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    got_pr = compiled(backend="pallas", **feeds)
    program_s = time.perf_counter() - t0
    assert np.array_equal(got_po, ref) and np.array_equal(got_pr, ref)
    assert_fast_path(compiled.last_stats)          # zero eager GEMMs
    print(f"\nconv chain {hw}x{hw}x{ch} ({compiled.describe()}):")
    print(f"{'pallas':<10} {per_op_s:>10.3f} {program_s:>10.3f} "
          f"{per_op_s / max(program_s, 1e-9):>7.2f}x   (eager GEMMs: "
          f"{sum(s.eager_gemm_insns for s in compiled.last_stats)})")
    return dict(per_op_s=round(per_op_s, 4), program_s=round(program_s, 4),
                exact=True)


def _serve_ab(build, feeds, ref, calls: int) -> dict:
    """One serving A/B: the fast path (buffer fences + pre-staged
    streams/constants + batched tile dispatch + decode cache) vs the PR-3
    baseline configuration (join barriers, per-call restaging, per-tile
    dispatch, per-call decode).  Wall calls/sec on the Pallas engine
    (host metric), per-call staging bytes, DRAM growth, and TimingModel
    cycles under the template's OWN §2.6 memory system (the architectural
    metric — the fence-pipelining win lives in the DMA/compute overlap,
    which the host-calibrated constants hide because host memcpy is
    orders of magnitude faster relative to interpret-mode compute)."""
    from repro.core.backend import PallasBackend
    from repro.core.simulator import TimingModel

    tspec = hwspec.pynq()
    modes = {}
    for label, fence_mode, prestage, eng in (
            ("fast", "buffer", True, PallasBackend()),
            ("baseline", "barrier", False,
             PallasBackend(batch_tiles=False, cache_decode=False))):
        compiled = build().compile(use_cache=False, fence_mode=fence_mode,
                                   prestage=prestage)
        out = compiled(backend=eng, **feeds)           # warm jit caches
        exact = bool(np.array_equal(out, ref))
        assert exact, (f"{label} serving mode diverged from the reference "
                       "— refusing to publish speedups for wrong results")
        dram_before = compiled.device.dram._next
        wall = float("inf")                            # best-of-3 loops
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(calls):
                compiled(backend=eng, **feeds)
            wall = min(wall, time.perf_counter() - t0)
        growth = compiled.device.dram._next - dram_before
        # cycle totals from the calibrated TimingModel (same streams)
        compiled(backend=eng, timing=TimingModel(tspec), **feeds)
        cycles = sum(st.total_cycles for st in compiled.last_stats)
        modes[label] = dict(
            fence_mode=fence_mode, prestage=prestage,
            calls_per_sec=round(calls / wall, 1),
            staging_bytes_per_call=compiled.last_staging_bytes,
            dram_growth_bytes_over_calls=int(growth),
            n_fences=compiled.n_fences, n_barriers=compiled.n_barriers,
            total_cycles=int(cycles),
            tiles_resolved=sum(st.tiles_resolved
                               for st in compiled.last_stats),
            tile_batches=sum(st.tile_batches
                             for st in compiled.last_stats),
            exact=exact)
    fast, base = modes["fast"], modes["baseline"]
    return dict(
        modes=modes,
        speedup_wall_x=round(
            fast["calls_per_sec"] / max(base["calls_per_sec"], 1e-9), 2),
        speedup_cycles_x=round(
            base["total_cycles"] / max(fast["total_cycles"], 1), 3),
        staging_bytes_saved_per_call=(base["staging_bytes_per_call"]
                                      - fast["staging_bytes_per_call"]))


def run_serving(calls: int = 100, out_json: str | None = None,
                quiet: bool = False) -> dict:
    """Serving-loop mode: fence+prestage fast path vs the barrier+restage
    PR-3 baseline on two dependent 2-layer chains (conv 3x3 -> 1x1, and a
    matmul MLP whose weight tiles are large enough for the cross-boundary
    weight double-buffering to dominate the fence win).  Writes
    ``benchmarks/BENCH_serving.json`` so the perf trajectory is tracked
    across PRs."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(2)
    ep = Epilogue(shift=6, relu=True)

    # 2-layer conv chain
    hw_, ch = 14, 32
    s1 = ConvShape(n=1, h=hw_, w=hw_, ic=ch, oc=ch, kh=3, kw=3,
                   stride=1, pad=1)
    s2 = ConvShape(n=1, h=hw_, w=hw_, ic=ch, oc=ch, kh=1, kw=1,
                   stride=1, pad=0)
    x = rng.integers(-64, 64, size=(1, ch, hw_, hw_), dtype=np.int8)
    k1 = rng.integers(-16, 16, size=(ch, ch, 3, 3), dtype=np.int8)
    k2 = rng.integers(-16, 16, size=(ch, ch, 1, 1), dtype=np.int8)
    conv_ref = conv2d_reference(conv2d_reference(x, k1, s1, epilogue=ep),
                                k2, s2, epilogue=ep)

    def build_conv():
        p = Program(spec)
        t = p.conv2d(p.input("x", x.shape), p.constant("k1", k1), s1,
                     epilogue=ep, name="c1")
        p.conv2d(t, p.constant("k2", k2), s2, epilogue=ep, name="c2")
        return p

    # 2-layer matmul chain
    m, d = 128, 256
    xa = rng.integers(-128, 128, size=(m, d), dtype=np.int8)
    w1 = rng.integers(-128, 128, size=(d, d), dtype=np.int8)
    w2 = rng.integers(-128, 128, size=(d, d), dtype=np.int8)
    mlp_ref = matmul_reference(matmul_reference(xa, w1, ep), w2, ep)

    def build_mlp():
        p = Program(spec)
        t = p.matmul(p.input("x", xa.shape), p.constant("w1", w1),
                     epilogue=ep, name="m1")
        p.matmul(t, p.constant("w2", w2), epilogue=ep, name="m2")
        return p

    result = {"calls": calls, "workloads": {}}
    result["workloads"][f"conv3x3->conv1x1 {hw_}x{hw_}x{ch}"] = \
        _serve_ab(build_conv, dict(x=x), conv_ref, calls)
    result["workloads"][f"matmul {m}x{d} -> {d}x{d} x2"] = \
        _serve_ab(build_mlp, dict(x=xa), mlp_ref, calls)

    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_serving.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        for name, r in result["workloads"].items():
            print(f"\nserving loop ({name}, {calls} calls):")
            for label in ("fast", "baseline"):
                mm = r["modes"][label]
                print(f"  {label:<9} {mm['calls_per_sec']:>8} calls/s, "
                      f"{mm['staging_bytes_per_call']:>7} B staged/call, "
                      f"DRAM growth {mm['dram_growth_bytes_over_calls']} B, "
                      f"{mm['total_cycles']:>8} cycles "
                      f"({mm['n_fences']} fences, "
                      f"{mm['n_barriers']} barriers, "
                      f"{mm['tiles_resolved']} tiles / "
                      f"{mm['tile_batches']} launches)")
            print(f"  speedup: {r['speedup_wall_x']}x wall, "
                  f"{r['speedup_cycles_x']}x cycles")
        print(f"-> {out_json}")
    return result


def run_pool(requests: int = 64, out_json: str | None = None,
             quiet: bool = False) -> dict:
    """Pool-serving mode: one compiled artifact, N cloned pre-staged
    devices, `requests` concurrent submits sharded by the BatchServer.
    Measures aggregate calls/sec at pool sizes 1/2/4 on the Pallas
    engine (pool size 1 = no gang, the serial async baseline) plus the
    zero-per-call-DRAM invariant PER SLOT, and byte-checks every pooled
    output against serial execution before publishing numbers.  Writes
    ``benchmarks/BENCH_pool.json``.

    The scaling lever is the gang dispatch: requests parked on the pool
    run the identical pre-staged stream, so each kernel launch carries
    every gang member's tiles (shared constant weights row-concat into
    one GEMM that fills the padded row tile) — per-launch dispatch and
    padding waste are paid once per gang instead of once per request."""
    from repro.core.backend import PallasBackend
    from repro.core.serve import DevicePool

    spec = hwspec.pynq()
    rng = np.random.default_rng(3)
    ep = Epilogue(shift=6, relu=True)
    m, d, layers = 32, 64, 2
    ws = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
          for _ in range(layers)]
    prog = Program(spec)
    t = prog.input("x", (m, d))
    for i, w in enumerate(ws):
        t = prog.matmul(t, prog.constant(f"w{i}", w), epilogue=ep)
    compiled = prog.compile(use_cache=False)
    feeds = [{"x": rng.integers(-128, 128, size=(m, d), dtype=np.int8)}
             for _ in range(requests)]

    def ref(feed):
        r = feed["x"]
        for w in ws:
            r = matmul_reference(r, w, ep)
        return r

    eng = PallasBackend()
    result = {"requests": requests,
              "workload": f"matmul {m}x{d} -> {d}x{d} x{layers}, "
                          f"constant weights", "pools": {}}
    for size in (1, 2, 4):
        with DevicePool(compiled, size=size, backend=eng,
                        policy="least_loaded") as pool:
            # warm: jit caches for this gang width
            [f.wait(timeout=600) for f in
             [pool.submit(**fd) for fd in feeds[:2 * size]]]
            marks = [s.device.dram._next for s in pool.slots]
            wall = float("inf")
            for _ in range(3):                         # best-of-3
                t0 = time.perf_counter()
                futs = [pool.submit(**fd) for fd in feeds]
                outs = [f.wait(timeout=600) for f in futs]
                wall = min(wall, time.perf_counter() - t0)
            for o, fd in zip(outs, feeds):
                assert np.array_equal(o, ref(fd)), \
                    "pooled output diverged from serial reference — " \
                    "refusing to publish throughput for wrong results"
            growth = [s.device.dram._next - m0
                      for s, m0 in zip(pool.slots, marks)]
            stats = pool.slot_stats()
            result["pools"][str(size)] = dict(
                calls_per_sec=round(requests / wall, 1),
                wall_s=round(wall, 4),
                dram_growth_bytes_per_slot=growth,
                calls_per_slot=[s.calls for s in stats],
                ganged_steps=sum(s.ganged_steps for s in stats),
                tiles_resolved=sum(s.tiles_resolved for s in stats),
                tile_batches=sum(s.tile_batches for s in stats),
                exact=True)
            assert all(g == 0 for g in growth), \
                f"pool size {size}: per-call DRAM growth {growth}"
    p1 = result["pools"]["1"]["calls_per_sec"]
    p4 = result["pools"]["4"]["calls_per_sec"]
    result["speedup_4v1_x"] = round(p4 / max(p1, 1e-9), 2)

    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_pool.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        print(f"\npool serving ({result['workload']}, {requests} requests):")
        for size in ("1", "2", "4"):
            r = result["pools"][size]
            print(f"  pool {size}: {r['calls_per_sec']:>7} calls/s, "
                  f"{r['ganged_steps']} ganged steps, "
                  f"{r['tiles_resolved']} tiles / {r['tile_batches']} "
                  f"launches, DRAM growth {r['dram_growth_bytes_per_slot']}")
        print(f"  speedup pool4 vs pool1: {result['speedup_4v1_x']}x")
        print(f"-> {out_json}")
    return result


def run_decode(sessions: int = 4, steps: int = 32,
               out_json: str | None = None, quiet: bool = False) -> dict:
    """Autoregressive-decode serving: the quantized 2-block decoder
    (persistent KV caches, host attention segments) decodes `steps`
    tokens for `sessions` concurrent sessions at pool sizes 1 and 4 on
    the Pallas engine.  Pool 1 serializes the sessions on one slot
    (every step swaps the resident KV state in and out); pool 4 gives
    each session its own slot and gangs the same-step accelerator
    segments into shared kernel launches.  Reports aggregate decode
    steps/sec, p50/p99 per-step latency, and the per-slot DRAM-flat
    invariant, and byte-checks every step against the eager numpy
    reference before publishing numbers.  Writes
    ``benchmarks/BENCH_decode.json`` — the tail-latency baseline for
    later traffic-tier PRs."""
    from repro.core.backend import PallasBackend
    from repro.core.serve import DevicePool
    from repro.models.vta_decoder import QuantDecoder

    dec = QuantDecoder()
    if 2 + steps > dec.cfg.s_max:
        raise ValueError(f"steps {steps} + warmup exceed the KV capacity "
                         f"{dec.cfg.s_max}")
    compiled = dec.compile(use_cache=False)
    eng = PallasBackend()
    result = {"sessions": sessions, "steps": steps,
              "workload": f"quantized {dec.cfg.n_blocks}-block decoder, "
                          f"d={dec.cfg.d_model}, persistent KV "
                          f"({compiled.persistent_bytes}B/session)",
              "pools": {}}
    for size in (1, 4):
        with DevicePool(compiled, size=size, backend=eng) as pool:
            sess = [pool.session() for _ in range(sessions)]
            refs = [dec.reference() for _ in range(sessions)]
            rng = np.random.default_rng(17)
            for _ in range(2):                         # warm jit caches
                xs = [rng.integers(-32, 32, (1, dec.cfg.d_model), np.int8)
                      for _ in range(sessions)]
                futs = [s.submit(x=x) for s, x in zip(sess, xs)]
                for f, r, x in zip(futs, refs, xs):
                    assert np.array_equal(f.wait(300), r.step(x))
            pool.drain()
            marks = [len(s.device.dram._allocs) for s in pool.slots]
            lat = []
            t0 = time.perf_counter()
            for _ in range(steps):
                xs = [rng.integers(-32, 32, (1, dec.cfg.d_model), np.int8)
                      for _ in range(sessions)]
                ts = time.perf_counter()
                futs = [s.submit(x=x) for s, x in zip(sess, xs)]
                for f, r, x in zip(futs, refs, xs):
                    got = f.wait(300)
                    lat.append(time.perf_counter() - ts)
                    assert np.array_equal(got, r.step(x)), \
                        "pooled decode diverged from the eager numpy " \
                        "reference — refusing to publish throughput"
            wall = time.perf_counter() - t0
            pool.drain()
            flat = marks == [len(s.device.dram._allocs)
                             for s in pool.slots]
            assert flat, f"pool {size}: DRAM allocations grew during decode"
            stats = pool.slot_stats()
            lat_ms = np.sort(np.array(lat) * 1e3)
            result["pools"][str(size)] = dict(
                steps_per_sec=round(sessions * steps / wall, 1),
                wall_s=round(wall, 4),
                p50_step_ms=round(float(np.percentile(lat_ms, 50)), 3),
                p99_step_ms=round(float(np.percentile(lat_ms, 99)), 3),
                ganged_steps=sum(s.ganged_steps for s in stats),
                session_swaps=sum(s.session_swaps for s in stats),
                persist_hiwater_bytes=[s.persist_hiwater for s in stats],
                dram_flat=flat, exact=True)
    p1 = result["pools"]["1"]["steps_per_sec"]
    p4 = result["pools"]["4"]["steps_per_sec"]
    result["speedup_4v1_x"] = round(p4 / max(p1, 1e-9), 2)

    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_decode.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        print(f"\ndecode serving ({result['workload']}; {sessions} "
              f"sessions x {steps} steps):")
        for size in ("1", "4"):
            r = result["pools"][size]
            print(f"  pool {size}: {r['steps_per_sec']:>7} steps/s agg, "
                  f"p50 {r['p50_step_ms']} ms, p99 {r['p99_step_ms']} ms, "
                  f"{r['ganged_steps']} ganged steps, "
                  f"{r['session_swaps']} KV swaps, DRAM flat")
        print(f"  speedup pool4 vs pool1: {result['speedup_4v1_x']}x")
        print(f"-> {out_json}")
    return result


def run_lowbit(calls: int = 20, out_json: str | None = None,
               quiet: bool = False) -> dict:
    """Sub-byte weight path: packed int4/int2 constant images + the
    LUT-GEMM decode kernel.  Compiles the same weight-stationary matmul
    at wgt_bits 8/4/2, reports the staged constant-image shrink (must be
    >= 2x at int4 — the DevicePool clone-cost lever), byte-checks the
    int4 program across both engines against the numpy packed reference,
    and A/Bs the LUT kernel vs the dense GEMM on a decode-shaped call.
    Writes ``benchmarks/BENCH_lowbit.json``."""
    from repro.core.backend import PallasBackend, SimulatorBackend

    n, k, m = 256, 256, 2            # decode shape: 2 rows, 256x256 weight
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
    shift = 6

    def build(bits):
        spec = hwspec.pynq() if bits == 8 else hwspec.lowbit(bits)
        qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        w = rng.integers(qmin, qmax + 1, size=(n, k)).astype(np.int8)
        prog = Program(spec)
        xi = prog.input("x", x.shape)
        prog.matmul(xi, prog.constant("w", w), epilogue=Epilogue(shift=shift))
        return prog.compile(use_cache=False), w

    result = dict(workload=f"matmul {m}x{k} @ const {n}x{k}", bits={})
    ref_bytes = None
    for bits in (8, 4, 2):
        compiled, w = build(bits)
        want = np.clip(
            (x.astype(np.int64) @ w.T.astype(np.int64)) >> shift,
            -128, 127).astype(np.int8)
        got_sim = compiled(backend=SimulatorBackend(), x=x)
        got_pl = compiled(backend=PallasBackend(), x=x)
        exact = (np.array_equal(got_sim, want)
                 and np.array_equal(got_pl, want))
        assert exact, f"bits={bits} engines disagree with reference"
        lut = sum(s.lut_launches for s in compiled.last_stats)

        be_lut = PallasBackend(use_lut=True) if bits < 8 else None
        be_dense = PallasBackend(use_lut=False)
        compiled(backend=be_dense, x=x)           # warm jit caches
        t0 = time.perf_counter()
        for _ in range(calls):
            compiled(backend=be_dense, x=x)
        dense_s = (time.perf_counter() - t0) / calls
        lut_s = None
        if be_lut is not None:
            compiled(backend=be_lut, x=x)
            t0 = time.perf_counter()
            for _ in range(calls):
                compiled(backend=be_lut, x=x)
            lut_s = (time.perf_counter() - t0) / calls

        if bits == 8:
            ref_bytes = compiled.const_bytes
        row = dict(const_bytes=compiled.const_bytes,
                   dram_bytes=compiled.device.dram._next,
                   shrink_x=round(ref_bytes / compiled.const_bytes, 2),
                   exact_both_engines=exact,
                   lut_launches_auto=lut,
                   dense_us_per_call=round(dense_s * 1e6, 1),
                   lut_us_per_call=(round(lut_s * 1e6, 1)
                                    if lut_s is not None else None))
        result["bits"][str(bits)] = row
    assert result["bits"]["4"]["shrink_x"] >= 2.0, \
        "int4 constant image must shrink >= 2x vs int8"

    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_lowbit.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        print(f"\nlowbit weights ({result['workload']}, {calls} calls):")
        print(f"{'bits':>4} {'const B':>8} {'shrink':>7} {'exact':>6} "
              f"{'dense us':>9} {'lut us':>8} {'lut auto':>8}")
        for bits in ("8", "4", "2"):
            r = result["bits"][bits]
            lut_us = r["lut_us_per_call"]
            print(f"{bits:>4} {r['const_bytes']:>8} "
                  f"{r['shrink_x']:>6.1f}x {str(r['exact_both_engines']):>6} "
                  f"{r['dense_us_per_call']:>9} "
                  f"{lut_us if lut_us is not None else '-':>8} "
                  f"{r['lut_launches_auto']:>8}")
        print(f"-> {out_json}")
    return result


def run_autotune(seed: int = 0, candidates: int = 12, top: int = 4,
                 repeats: int = 5, deep: bool = False,
                 out_json: str | None = None, quiet: bool = False) -> dict:
    """Design-space autotuner trajectory (paper §4): seeded two-stage
    search — TimingModel replay as the cheap oracle over every sampled
    candidate, wall measurement + cross-engine byte validation for the
    top-N — over one conv and one matmul workload around the default
    pynq template.  Asserts the winner is validated AND beats the
    unmodified base by >= 1.1x measured, then demonstrates the tuning
    cache: recompiling the winner's program must be all hits.  Records
    predicted-vs-measured for every stage-2 candidate and writes
    ``benchmarks/BENCH_autotune.json``.  ``deep=True`` (nightly) widens
    the sampled grid."""
    from repro.core import autotune
    from repro.core.program import op_signature

    if deep:
        candidates, top, repeats = 64, 8, repeats
    base = hwspec.pynq()
    cache = autotune.TuningCache()      # local: don't pollute the global
    workloads = [
        autotune.conv_workload(ConvShape(n=1, h=14, w=14, ic=32, oc=32,
                                         kh=3, kw=3, stride=1, pad=1),
                               seed=seed),
        autotune.matmul_workload(64, 128, 128, seed=seed),
    ]
    say = (lambda s: None) if quiet else print
    result = dict(seed=seed, base_spec=autotune.spec_key(base),
                  deep=deep, workloads=[])
    for wl in workloads:
        res = autotune.search(wl, base_spec=base, seed=seed,
                              n_candidates=candidates, top_n=top,
                              repeats=repeats, cache=cache, log=say)
        assert res.winner is not None and res.winner.validated, \
            f"{wl.name}: no validated winner"
        assert res.winner.predicted_cycles < res.baseline.predicted_cycles, \
            f"{wl.name}: winner does not beat default pynq on the oracle"
        assert res.speedup_measured >= 1.1, \
            f"{wl.name}: measured speedup {res.speedup_measured:.2f}x < 1.1x"
        # the cache round-trip: rebuild the winner's program and compile —
        # every accel op must now resolve from the tuning records
        prog, _, _ = wl.build(res.winner.candidate.spec,
                              res.winner.candidate.virtual_threads,
                              res.winner.candidate.lowering)
        n_ops = sum(1 for n in prog.nodes if n.op in ("conv2d", "matmul"))
        gc = autotune.global_cache()
        snap = (dict(gc.entries), gc.hits, gc.misses)
        try:
            gc.entries = dict(cache.entries)
            recompiled = prog.compile(use_cache=False)
        finally:
            gc.entries, gc.hits, gc.misses = snap
        assert recompiled.tune_hits == n_ops and recompiled.tune_misses == 0
        result["workloads"].append(
            {**res.to_json(), "recompile_tune_hits": recompiled.tune_hits})

    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_autotune.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        print(f"\nautotune trajectory (seed {seed}, {candidates} "
              f"candidates, top-{top}):")
        print(f"{'workload':<24} {'pred x':>7} {'meas x':>7} "
              f"{'winner':>34} {'hits':>5}")
        for w in result["workloads"]:
            print(f"{w['workload']:<24} {w['speedup_predicted']:>6.2f}x "
                  f"{w['speedup_measured']:>6.2f}x "
                  f"{w['winner']['candidate']:>34} "
                  f"{w['recompile_tune_hits']:>5}")
        print(f"-> {out_json}")
    return result


if __name__ == "__main__":
    run()
    run_conv()
    run_serving()
    run_pool()
    run_decode()
    run_lowbit()
    run_autotune()
