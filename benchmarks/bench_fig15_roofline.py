"""Paper Fig. 15: roofline of ResNet-18 conv layers on VTA, with and
without virtual threading (latency hiding).

For every FPGA-offloadable Table-1 layer, the runtime JITs the real
instruction stream (vt=1 and vt=2), the cycle-level simulator executes it
through the decoupled access-execute pipeline, and we report achieved
GOPS vs the hardware roofline.  The paper's claim: peak compute
utilization rises from ~70% (no virtual threads) to ~88% (virtual
threads on).
"""
from __future__ import annotations

import csv
import io
from typing import List

from repro.core import hwspec
from repro.core.pipeline_model import (RooflinePoint, conv_roofline_point,
                                       hardware_roofline,
                                       peak_compute_utilization)
from repro.core.workloads import resnet18_table1


def run(quiet: bool = False):
    spec = hwspec.pynq()
    rows = []
    points = {1: [], 2: []}
    for layer in resnet18_table1():
        if layer.cpu_only:
            continue
        for vt in (1, 2):
            p = conv_roofline_point(spec, layer.shape, layer.name, vt)
            points[vt].append(p)
            rows.append({
                "layer": layer.name, "virtual_threads": vt,
                "intensity_ops_per_byte": round(p.arithmetic_intensity, 2),
                "gops": round(p.gops, 2),
                "roofline_gops": round(p.roofline_gops, 2),
                "roofline_fraction": round(p.roofline_fraction, 3),
                "compute_utilization": round(p.utilization, 3),
                "total_cycles": p.total_cycles,
            })
    u1 = peak_compute_utilization(points[1])
    u2 = peak_compute_utilization(points[2])
    if not quiet:
        w = csv.DictWriter(io.StringIO(), fieldnames=rows[0].keys())
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
        print(f"\npeak_compute_utilization_vt1,{u1:.3f}")
        print(f"peak_compute_utilization_vt2,{u2:.3f}")
        print(f"paper_claim,0.70->0.88")
    return rows, u1, u2


def main() -> None:
    run()


if __name__ == "__main__":
    main()
