"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU time — the
value here is the oracle check + the derived-from-spec static analysis of
each kernel's VMEM working set and arithmetic intensity), plus the
execution-backend comparison: the same encoded task-ISA stream through
the cycle-capable simulator vs the Pallas engine."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwspec
from repro.core.runtime import Runtime
from repro.core.scheduler import (matmul_reference, read_matmul_result,
                                  schedule_matmul)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.vta_gemm import vta_gemm, vta_gemm_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quiet: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    # vta_gemm: VMEM working set at (128,128,128) int8 blocks
    a = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    us_ref = _time(lambda: vta_gemm(a, w, use_pallas=False))
    us_pl = _time(lambda: vta_gemm(a, w, use_pallas=True, interpret=True))
    ok = bool(jnp.array_equal(vta_gemm(a, w, use_pallas=True, interpret=True),
                              vta_gemm_ref(a, w)))
    vmem_kib = (128 * 128 + 128 * 128 + 128 * 128 * 4 + 128 * 128 * 4) / 1024
    rows.append({"kernel": "vta_gemm_256", "us_ref": round(us_ref, 1),
                 "us_interpret": round(us_pl, 1), "exact": ok,
                 "vmem_working_set_kib": vmem_kib,
                 "intensity_flops_per_byte": round(
                     2 * 256 ** 3 / (3 * 256 * 256), 1)})
    # flash attention block analysis
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    us_f = _time(lambda: flash_attention(q, k, k, use_pallas=True,
                                         interpret=True, bq=128, bk=128))
    close = bool(jnp.allclose(
        flash_attention(q, k, k, use_pallas=True, interpret=True,
                        bq=128, bk=128),
        flash_attention(q, k, k, use_pallas=False), atol=2e-5))
    rows.append({"kernel": "flash_attn_512", "us_ref": "-",
                 "us_interpret": round(us_f, 1), "exact": close,
                 "vmem_working_set_kib": (128 * 64 * 4 * 3 + 128 * 128 * 4) / 1024,
                 "intensity_flops_per_byte": round(
                     4 * 512 * 512 * 64 / (3 * 512 * 64 * 4), 1)})
    if not quiet:
        print(",".join(str(k) for k in rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def run_backends(size: int = 512, reps: int = 3, quiet: bool = False) -> dict:
    """Execution-backend comparison on one schedule_matmul stream: the
    decoded-stream Pallas engine must beat the per-uop numpy simulator by
    >= 10x on the size^3 workload while staying bit-exact.  Best-of-reps
    wall-clock per engine (first pallas rep additionally pays the one-time
    jit compile and is excluded by the warm-up call)."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, size=(size, size), dtype=np.int8)
    w = rng.integers(-128, 128, size=(size, size), dtype=np.int8)

    def one(backend):
        rt = Runtime(spec)
        plan = schedule_matmul(rt, a, w, virtual_threads=2)
        stats = rt.synchronize(backend=backend)
        return stats, read_matmul_result(rt, plan)

    one("pallas")                       # warm the jit caches once
    runs = {b: [one(b) for _ in range(reps)]
            for b in ("pallas", "simulator")}
    pal_s = min(s.wall_time_s for s, _ in runs["pallas"])
    sim_s = min(s.wall_time_s for s, _ in runs["simulator"])
    ref = matmul_reference(a, w)
    exact = all(np.array_equal(out, ref)
                for outs in runs.values() for _, out in outs)
    row = {"workload": f"matmul_{size}x{size}x{size}",
           "simulator_s": round(sim_s, 3),
           "pallas_s": round(pal_s, 3),
           "speedup_x": round(sim_s / max(pal_s, 1e-9), 1),
           "exact": exact}
    if not quiet:
        print(",".join(str(k) for k in row.keys()))
        print(",".join(str(v) for v in row.values()))
    return row


def main() -> None:
    run()
    run_backends()


if __name__ == "__main__":
    main()
