"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU time — the
value here is the oracle check + the derived-from-spec static analysis of
each kernel's VMEM working set and arithmetic intensity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.vta_gemm import vta_gemm, vta_gemm_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quiet: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    # vta_gemm: VMEM working set at (128,128,128) int8 blocks
    a = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    us_ref = _time(lambda: vta_gemm(a, w, use_pallas=False))
    us_pl = _time(lambda: vta_gemm(a, w, use_pallas=True, interpret=True))
    ok = bool(jnp.array_equal(vta_gemm(a, w, use_pallas=True, interpret=True),
                              vta_gemm_ref(a, w)))
    vmem_kib = (128 * 128 + 128 * 128 + 128 * 128 * 4 + 128 * 128 * 4) / 1024
    rows.append({"kernel": "vta_gemm_256", "us_ref": round(us_ref, 1),
                 "us_interpret": round(us_pl, 1), "exact": ok,
                 "vmem_working_set_kib": vmem_kib,
                 "intensity_flops_per_byte": round(
                     2 * 256 ** 3 / (3 * 256 * 256), 1)})
    # flash attention block analysis
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    us_f = _time(lambda: flash_attention(q, k, k, use_pallas=True,
                                         interpret=True, bq=128, bk=128))
    close = bool(jnp.allclose(
        flash_attention(q, k, k, use_pallas=True, interpret=True,
                        bq=128, bk=128),
        flash_attention(q, k, k, use_pallas=False), atol=2e-5))
    rows.append({"kernel": "flash_attn_512", "us_ref": "-",
                 "us_interpret": round(us_f, 1), "exact": close,
                 "vmem_working_set_kib": (128 * 64 * 4 * 3 + 128 * 128 * 4) / 1024,
                 "intensity_flops_per_byte": round(
                     4 * 512 * 512 * 64 / (3 * 512 * 64 * 4), 1)})
    if not quiet:
        print(",".join(str(k) for k in rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def main() -> None:
    run()


if __name__ == "__main__":
    main()
