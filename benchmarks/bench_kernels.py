"""Kernel microbenchmarks (interpret-mode wall time is NOT TPU time — the
value here is the oracle check + the derived-from-spec static analysis of
each kernel's VMEM working set and arithmetic intensity), plus the
execution-backend comparison: the same encoded task-ISA stream through
the cycle-capable simulator vs the Pallas engine."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwspec
from repro.core.runtime import Runtime
from repro.core.scheduler import (matmul_reference, read_matmul_result,
                                  schedule_matmul)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.vta_gemm import vta_gemm, vta_gemm_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quiet: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    # vta_gemm: VMEM working set at (128,128,128) int8 blocks
    a = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    us_ref = _time(lambda: vta_gemm(a, w, use_pallas=False))
    us_pl = _time(lambda: vta_gemm(a, w, use_pallas=True, interpret=True))
    ok = bool(jnp.array_equal(vta_gemm(a, w, use_pallas=True, interpret=True),
                              vta_gemm_ref(a, w)))
    vmem_kib = (128 * 128 + 128 * 128 + 128 * 128 * 4 + 128 * 128 * 4) / 1024
    rows.append({"kernel": "vta_gemm_256", "us_ref": round(us_ref, 1),
                 "us_interpret": round(us_pl, 1), "exact": ok,
                 "vmem_working_set_kib": vmem_kib,
                 "intensity_flops_per_byte": round(
                     2 * 256 ** 3 / (3 * 256 * 256), 1)})
    # flash attention block analysis
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    us_f = _time(lambda: flash_attention(q, k, k, use_pallas=True,
                                         interpret=True, bq=128, bk=128))
    close = bool(jnp.allclose(
        flash_attention(q, k, k, use_pallas=True, interpret=True,
                        bq=128, bk=128),
        flash_attention(q, k, k, use_pallas=False), atol=2e-5))
    rows.append({"kernel": "flash_attn_512", "us_ref": "-",
                 "us_interpret": round(us_f, 1), "exact": close,
                 "vmem_working_set_kib": (128 * 64 * 4 * 3 + 128 * 128 * 4) / 1024,
                 "intensity_flops_per_byte": round(
                     4 * 512 * 512 * 64 / (3 * 512 * 64 * 4), 1)})
    if not quiet:
        print(",".join(str(k) for k in rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def run_backends(size: int = 512, reps: int = 3, quiet: bool = False) -> dict:
    """Execution-backend comparison on one schedule_matmul stream: the
    decoded-stream Pallas engine must beat the per-uop numpy simulator by
    >= 10x on the size^3 workload while staying bit-exact.  Best-of-reps
    wall-clock per engine (first pallas rep additionally pays the one-time
    jit compile and is excluded by the warm-up call)."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, size=(size, size), dtype=np.int8)
    w = rng.integers(-128, 128, size=(size, size), dtype=np.int8)

    def one(backend):
        rt = Runtime(spec)
        plan = schedule_matmul(rt, a, w, virtual_threads=2)
        stats = rt.synchronize(backend=backend)
        return stats, read_matmul_result(rt, plan)

    one("pallas")                       # warm the jit caches once
    runs = {b: [one(b) for _ in range(reps)]
            for b in ("pallas", "simulator")}
    pal_s = min(s.wall_time_s for s, _ in runs["pallas"])
    sim_s = min(s.wall_time_s for s, _ in runs["simulator"])
    ref = matmul_reference(a, w)
    exact = all(np.array_equal(out, ref)
                for outs in runs.values() for _, out in outs)
    row = {"workload": f"matmul_{size}x{size}x{size}",
           "simulator_s": round(sim_s, 3),
           "pallas_s": round(pal_s, 3),
           "speedup_x": round(sim_s / max(pal_s, 1e-9), 1),
           "exact": exact}
    if not quiet:
        print(",".join(str(k) for k in row.keys()))
        print(",".join(str(v) for v in row.values()))
    return row


def fit_timing_constants(spec=None, quiet: bool = False) -> dict:
    """Calibrate TimingModel DMA/compute constants against MEASURED Pallas
    kernel times on this host, so ``RunStats.total_cycles`` predicts
    wall-clock on the Pallas engine (the ROADMAP calibration item).

    Model being fitted (see ``TimingModel``):
      * GEMM insn latency = #matrix-multiplies cycles, i.e. the spec's
        ``macs_per_cycle`` per cycle -> fit ``freq_mhz`` from the measured
        vta_gemm MAC rate (one warmed ``vta_gemm_pallas`` at 512^3);
      * DMA latency = ``dram_latency_cycles`` + bytes / ``bytes_per_cycle``
        -> fit bandwidth and fixed setup cost from a two-point host-memcpy
        measurement through the simulated DRAM (a 4 KiB and a 16 MiB
        write), converted to cycles at the fitted frequency.

    Returns the kwargs for ``hwspec.calibrated`` /
    ``HardwareSpec.replace``.  The constants fitted on the dev container
    are recorded as ``hwspec.HOST_FIT``.
    """
    from repro.core.driver import Dram
    from repro.kernels._compat import resolve_interpret
    from repro.kernels.vta_gemm.kernel import vta_gemm_pallas

    spec = spec or hwspec.pynq()
    rng = np.random.default_rng(0)
    n = 512
    a = jnp.asarray(rng.integers(-128, 128, (n, n)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (n, n)), jnp.int8)
    # auto-select like PallasBackend: native on real TPU (the ROADMAP
    # recalibration path), interpreter on CPU CI
    interpret = resolve_interpret(None)

    def gemm():
        return vta_gemm_pallas(a, w, epilogue="requant", shift=7,
                               interpret=interpret)

    us = _time(gemm)                       # warmed best-effort microseconds
    mac_rate = n ** 3 / (us / 1e6)         # MACs / second
    freq_hz = mac_rate / spec.macs_per_cycle
    freq_mhz = freq_hz / 1e6

    dram = Dram(1 << 25)
    small = np.zeros(4 * 1024, np.uint8)
    big = np.zeros(16 * 1024 * 1024, np.uint8)
    a0, a1 = dram.alloc(small.nbytes), dram.alloc(big.nbytes)

    def t_write(addr, arr, reps=5):
        dram.write(addr, arr)
        t0 = time.perf_counter()
        for _ in range(reps):
            dram.write(addr, arr)
        return (time.perf_counter() - t0) / reps

    ts, tb = t_write(a0, small), t_write(a1, big)
    bw = (big.nbytes - small.nbytes) / max(tb - ts, 1e-12)
    lat_s = max(ts - small.nbytes / bw, 0.0)
    fit = dict(freq_mhz=round(freq_mhz, 4),
               dram_rd_bytes_per_cycle=round(bw / freq_hz, 2),
               dram_wr_bytes_per_cycle=round(bw / freq_hz, 2),
               dram_latency_cycles=max(1, int(round(lat_s * freq_hz))))
    if not quiet:
        print(f"fitted: {mac_rate / 1e6:.1f} MMAC/s "
              f"-> freq {freq_mhz:.3f} MHz; "
              f"DMA {bw / 1e9:.2f} GB/s "
              f"-> {fit['dram_rd_bytes_per_cycle']} B/cycle, "
              f"latency {fit['dram_latency_cycles']} cycles")
        print("hwspec.calibrated() kwargs:", fit)
    return fit


def run_fit_check(quiet: bool = False) -> dict:
    """Sanity row: cycles from the calibrated TimingModel on the Pallas
    engine vs its measured wall-clock for one schedule_matmul stream —
    the two should agree within a small factor (the calibration's whole
    point; interpret-mode timings are host-dependent, so the gate is
    loose)."""
    from repro.core.simulator import TimingModel

    fit = fit_timing_constants(quiet=True)
    spec = hwspec.pynq().replace(**fit)
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, size=(256, 256), dtype=np.int8)
    w = rng.integers(-128, 128, size=(256, 256), dtype=np.int8)
    rt = Runtime(spec)
    schedule_matmul(rt, a, w, virtual_threads=2)
    rt.synchronize(backend="pallas", keep_stream=True)   # warm jit
    rt.reset_stream()
    rt2 = Runtime(spec)
    schedule_matmul(rt2, a, w, virtual_threads=2)
    stats = rt2.synchronize(backend="pallas", timing=TimingModel(spec))
    predicted_s = stats.total_cycles / (spec.freq_mhz * 1e6)
    row = {"fit": fit, "total_cycles": stats.total_cycles,
           "predicted_s": round(predicted_s, 4),
           "wall_s": round(stats.wall_time_s, 4),
           "ratio": round(stats.wall_time_s / max(predicted_s, 1e-12), 2)}
    if not quiet:
        print(f"calibration check: predicted {row['predicted_s']}s vs "
              f"wall {row['wall_s']}s (ratio {row['ratio']}x)")
    return row


def main() -> None:
    run()
    run_backends()
    run_fit_check()


if __name__ == "__main__":
    main()
