"""§Roofline table: aggregate experiments/dryrun/*.json into the
EXPERIMENTS.md table (one row per compiled cell)."""
from __future__ import annotations

import glob
import json
import os

COLS = ("arch", "shape", "mesh", "quantized", "compute_ms", "memory_ms",
        "collective_ms", "dominant", "useful_ratio", "gib_per_dev",
        "roofline_fraction")


def rows(dirname: str = "experiments/dryrun", tagged: bool = False):
    out = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        base = os.path.basename(f)
        if not tagged and base.count("__") > 2:
            pass  # tagged variants included too; caller filters
        d = json.load(open(f))
        r = d["roofline"]
        out.append({
            "arch": d["arch"], "shape": d["shape"],
            "mesh": "multi" if "multi" in d["mesh"] else "single",
            "quantized": d.get("quantized", False),
            "tag": base,
            "compute_ms": r["compute_term_s"] * 1e3,
            "memory_ms": r["memory_term_s"] * 1e3,
            "collective_ms": r["collective_term_s"] * 1e3,
            "dominant": r["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "gib_per_dev": d["memory"].get("total_bytes_per_device", 0) / 2**30,
            "roofline_fraction": r.get("roofline_fraction", 0.0),
        })
    return out


def run(quiet: bool = False):
    rs = rows()
    if not quiet:
        print(",".join(COLS))
        for r in rs:
            print(",".join(
                f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
                for c in COLS))
        print(f"\ntotal_cells,{len(rs)}")
    return rs


def main() -> None:
    run()


if __name__ == "__main__":
    main()
