"""Paper Fig. 16: end-to-end ResNet-18 inference, CPU-only vs CPU+VTA.

Conv layers C2..C12 are offloaded to VTA (timed by the cycle-level
simulator over the real JIT'd instruction streams); C1 and the non-conv
residue (pooling, FC, residual adds) run on the modeled ARM Cortex-A9.
The paper reports: >3 s CPU-only -> <0.5 s offloaded, ~40x speedup on
offloaded conv layers.

``run_measured()`` complements the model with *measured* execution: the
real C2 stream on PallasBackend with the direct-conv coalescer on vs off
(``coalesce_subgrids=False`` — the pre-generalization eager path kh*kw>1
layers used to take), recording the fast-path speedup and the eager/
coalesced instruction counts.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import hwspec
from repro.core.backend import PallasBackend
from repro.core.conv import conv2d_reference, read_conv_result, \
    schedule_conv2d
from repro.core.pipeline_model import conv_roofline_point
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue
from repro.core.workloads import (CPU_EFFECTIVE_GOPS, CPU_RESIDUE_SECONDS,
                                  layer_by_name, resnet18_table1)


def run(quiet: bool = False):
    spec = hwspec.pynq()
    rows = []
    cpu_total = CPU_RESIDUE_SECONDS
    off_total = CPU_RESIDUE_SECONDS
    conv_cpu = conv_vta = 0.0
    for layer in resnet18_table1():
        gop = layer.shape.gops * layer.repeat
        t_cpu = gop / CPU_EFFECTIVE_GOPS
        if layer.cpu_only:
            t_vta = t_cpu
            util = 0.0
        else:
            p = conv_roofline_point(spec, layer.shape, layer.name,
                                    virtual_threads=2)
            t_vta = layer.repeat * p.total_cycles / (spec.freq_mhz * 1e6)
            util = p.utilization
            conv_cpu += t_cpu
            conv_vta += t_vta
        cpu_total += t_cpu
        off_total += t_vta
        rows.append({"layer": layer.name, "repeat": layer.repeat,
                     "gop": round(gop, 3),
                     "cpu_seconds": round(t_cpu, 4),
                     "vta_seconds": round(t_vta, 4),
                     "speedup": round(t_cpu / t_vta, 1),
                     "vta_utilization": round(util, 3)})
    if not quiet:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
        print(f"\ncpu_only_total_s,{cpu_total:.3f}")
        print(f"cpu_plus_vta_total_s,{off_total:.3f}")
        print(f"offloaded_conv_speedup,{conv_cpu / max(conv_vta, 1e-9):.1f}x")
        print("paper_claim,>3s -> <0.5s; ~40x conv speedup")
    return rows, cpu_total, off_total, conv_cpu / max(conv_vta, 1e-9)


def run_measured(layer: str = "C2", quiet: bool = False):
    """Measured (not modeled) Pallas execution of one kh*kw>1 conv layer:
    the identical encoded stream with the tile coalescer generalized to
    the direct-conv structure vs the pre-PR exact-grid-only behavior that
    sent every conv GEMM to the eager numpy loop."""
    shape = layer_by_name(layer).shape
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    x = rng.integers(-64, 64, size=(shape.n, shape.ic, shape.h, shape.w),
                     dtype=np.int8)
    w = rng.integers(-16, 16,
                     size=(shape.oc, shape.ic, shape.kh, shape.kw),
                     dtype=np.int8)
    ep = Epilogue(shift=6, relu=True)
    want = conv2d_reference(x, w, shape, epilogue=ep)

    rows = []
    for backend, label in ((PallasBackend(), "pallas_coalesced"),
                           (PallasBackend(coalesce_subgrids=False),
                            "pallas_eager_conv")):
        # warm the one-time Pallas jit compile out of the measurement
        rt = Runtime(spec)
        schedule_conv2d(rt, x, w, shape, epilogue=ep, virtual_threads=2)
        rt.synchronize(backend=backend)
        rt = Runtime(spec)
        plan = schedule_conv2d(rt, x, w, shape, epilogue=ep,
                               virtual_threads=2)
        t0 = time.perf_counter()
        stats = rt.synchronize(backend=backend)
        dt = time.perf_counter() - t0
        exact = bool(np.array_equal(read_conv_result(rt, plan), want))
        rows.append(dict(engine=label, seconds=round(dt, 3), exact=exact,
                         eager_gemms=stats.eager_gemm_insns,
                         coalesced_gemms=stats.coalesced_gemm_insns,
                         _dt=dt))
    speedup = rows[1].pop("_dt") / max(rows[0].pop("_dt"), 1e-9)
    if not quiet:
        print(f"\nmeasured {layer} ({shape.gops:.2f} GOP) on PallasBackend:")
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
        print(f"conv_fast_path_speedup,{speedup:.1f}x")
    assert all(r["exact"] for r in rows)
    assert rows[0]["eager_gemms"] == 0, "coalesced run hit the eager loop"
    return rows, speedup


def main() -> None:
    run()
    run_measured()


if __name__ == "__main__":
    main()
