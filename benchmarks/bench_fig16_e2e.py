"""Paper Fig. 16: end-to-end ResNet-18 inference, CPU-only vs CPU+VTA.

Conv layers C2..C12 are offloaded to VTA (timed by the cycle-level
simulator over the real JIT'd instruction streams); C1 and the non-conv
residue (pooling, FC, residual adds) run on the modeled ARM Cortex-A9.
The paper reports: >3 s CPU-only -> <0.5 s offloaded, ~40x speedup on
offloaded conv layers.
"""
from __future__ import annotations

from repro.core import hwspec
from repro.core.pipeline_model import conv_roofline_point
from repro.core.workloads import (CPU_EFFECTIVE_GOPS, CPU_RESIDUE_SECONDS,
                                  resnet18_table1)


def run(quiet: bool = False):
    spec = hwspec.pynq()
    rows = []
    cpu_total = CPU_RESIDUE_SECONDS
    off_total = CPU_RESIDUE_SECONDS
    conv_cpu = conv_vta = 0.0
    for layer in resnet18_table1():
        gop = layer.shape.gops * layer.repeat
        t_cpu = gop / CPU_EFFECTIVE_GOPS
        if layer.cpu_only:
            t_vta = t_cpu
            util = 0.0
        else:
            p = conv_roofline_point(spec, layer.shape, layer.name,
                                    virtual_threads=2)
            t_vta = layer.repeat * p.total_cycles / (spec.freq_mhz * 1e6)
            util = p.utilization
            conv_cpu += t_cpu
            conv_vta += t_vta
        cpu_total += t_cpu
        off_total += t_vta
        rows.append({"layer": layer.name, "repeat": layer.repeat,
                     "gop": round(gop, 3),
                     "cpu_seconds": round(t_cpu, 4),
                     "vta_seconds": round(t_vta, 4),
                     "speedup": round(t_cpu / t_vta, 1),
                     "vta_utilization": round(util, 3)})
    if not quiet:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
        print(f"\ncpu_only_total_s,{cpu_total:.3f}")
        print(f"cpu_plus_vta_total_s,{off_total:.3f}")
        print(f"offloaded_conv_speedup,{conv_cpu / max(conv_vta, 1e-9):.1f}x")
        print("paper_claim,>3s -> <0.5s; ~40x conv speedup")
    return rows, cpu_total, off_total, conv_cpu / max(conv_vta, 1e-9)


def main() -> None:
    run()


if __name__ == "__main__":
    main()
