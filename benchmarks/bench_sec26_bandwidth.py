"""Paper §2.6: SRAM bandwidth needed to keep the GEMM core busy.

The paper derives, for BATCH=2, BLOCK_IN=BLOCK_OUT=16 at 200 MHz:
51.2 Gb/s (input buffer), 409.6 Gb/s (weight buffer), 204.8 Gb/s
(register file read; x2 with write-back).  The numbers fall out of the
HardwareSpec identities — this benchmark checks them and prints the same
derivation for the paper's evaluation build and the TPU-flavoured
template instance.
"""
from __future__ import annotations

from repro.core import hwspec


def run(quiet: bool = False):
    rows = []
    for name, spec in (("pynq_batch2_200MHz", hwspec.pynq_batch2()),
                       ("pynq_eval_100MHz", hwspec.pynq()),
                       ("tpu_like", hwspec.tpu_like())):
        bw = spec.gemm_sram_bandwidth_gbps
        rows.append({"config": name,
                     "inp_gbps": round(bw["inp"], 1),
                     "wgt_gbps": round(bw["wgt"], 1),
                     "acc_rw_gbps": round(bw["acc"], 1),
                     "peak_gops": round(spec.peak_gops, 1)})
    if not quiet:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
        print("paper_claim,51.2/409.6/204.8 Gb/s at BATCH=2 16x16 200MHz")
    return rows


def main() -> None:
    run()


if __name__ == "__main__":
    main()
