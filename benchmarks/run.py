"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines plus each
benchmark's own table.  The dry-run roofline table is included when
experiments/dryrun JSONs exist (produced by `python -m
repro.launch.dryrun --all`).
"""
from __future__ import annotations

import time


def _section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    from benchmarks import (bench_fig15_roofline, bench_fig16_e2e,
                            bench_kernels, bench_program,
                            bench_roofline_table, bench_sec26_bandwidth)

    summary = []

    _section("Paper Fig. 15: ResNet-18 roofline + latency hiding")
    t0 = time.perf_counter()
    rows, u1, u2 = bench_fig15_roofline.run()
    summary.append(("fig15_latency_hiding",
                    (time.perf_counter() - t0) * 1e6,
                    f"util {u1:.2f}->{u2:.2f} (paper 0.70->0.88)"))

    _section("Paper Fig. 16: end-to-end ResNet-18 offload")
    t0 = time.perf_counter()
    _, cpu_s, off_s, speedup = bench_fig16_e2e.run()
    summary.append(("fig16_e2e_offload", (time.perf_counter() - t0) * 1e6,
                    f"{cpu_s:.2f}s->{off_s:.2f}s conv x{speedup:.0f}"))

    _section("Paper Sec 2.6: GEMM-core SRAM bandwidth")
    t0 = time.perf_counter()
    bench_sec26_bandwidth.run()
    summary.append(("sec26_bandwidth", (time.perf_counter() - t0) * 1e6,
                    "derivation check"))

    _section("Kernel microbench (interpret mode + oracle check)")
    t0 = time.perf_counter()
    bench_kernels.run()
    summary.append(("kernels", (time.perf_counter() - t0) * 1e6, "oracle ok"))

    _section("Execution backends: simulator vs Pallas, one task-ISA stream")
    t0 = time.perf_counter()
    row = bench_kernels.run_backends()
    summary.append(("backends", (time.perf_counter() - t0) * 1e6,
                    f"x{row['speedup_x']} exact={row['exact']}"))

    _section("Program-level JIT: one stream vs per-op synchronize")
    t0 = time.perf_counter()
    prow = bench_program.run()
    summary.append(("program_jit", (time.perf_counter() - t0) * 1e6,
                    f"{prow['insns']} insns, "
                    f"x{prow['rows'][0]['speedup_x']} on sim"))

    _section("Pool serving: async device pool, gang dispatch (1/2/4 slots)")
    t0 = time.perf_counter()
    prow = bench_program.run_pool()
    summary.append(("pool_serving", (time.perf_counter() - t0) * 1e6,
                    f"x{prow['speedup_4v1_x']} pool4 vs pool1"))

    _section("Decode serving: persistent-KV decoder, 4 sessions, pool 1 vs 4")
    t0 = time.perf_counter()
    drow = bench_program.run_decode()
    summary.append(("decode_serving", (time.perf_counter() - t0) * 1e6,
                    f"x{drow['speedup_4v1_x']} pool4 vs pool1, "
                    f"p99 {drow['pools']['4']['p99_step_ms']}ms"))

    _section("Sub-byte weights: packed int4/int2 constants + LUT-GEMM")
    t0 = time.perf_counter()
    lrow = bench_program.run_lowbit()
    summary.append(("lowbit_weights", (time.perf_counter() - t0) * 1e6,
                    f"x{lrow['bits']['4']['shrink_x']} const shrink at int4, "
                    f"exact={lrow['bits']['4']['exact_both_engines']}"))

    _section("General conv2d fast path: coalesced vs eager (measured C2)")
    t0 = time.perf_counter()
    _, conv_speedup = bench_fig16_e2e.run_measured()
    summary.append(("conv_fast_path", (time.perf_counter() - t0) * 1e6,
                    f"x{conv_speedup:.1f} vs pre-PR eager path"))

    _section("Traffic smoke: continuous batching, open-loop arrivals")
    t0 = time.perf_counter()
    from benchmarks import loadgen
    trow = loadgen.run_traffic(smoke=True)
    mcell = next(iter(trow["matmul"]["traces"].values()))
    summary.append(("traffic_smoke", (time.perf_counter() - t0) * 1e6,
                    f"exact={mcell['modes']['windowed']['exact']} "
                    "(full: python -m benchmarks.loadgen)"))

    _section("Chaos smoke: self-healing pool under seeded fault injection")
    t0 = time.perf_counter()
    from benchmarks import bench_chaos
    crow = bench_chaos.run(smoke=True)
    summary.append(("chaos_smoke", (time.perf_counter() - t0) * 1e6,
                    f"exact={crow['exact']} ratio={crow['goodput_ratio']} "
                    "(full: python -m benchmarks.bench_chaos)"))

    _section("Autotune smoke: seeded DSE on the calibrated cycle oracle")
    t0 = time.perf_counter()
    arow = bench_program.run_autotune(candidates=12, top=4)
    summary.append(("autotune_smoke", (time.perf_counter() - t0) * 1e6,
                    " ".join(f"x{w['speedup_measured']:.2f}"
                             for w in arow["workloads"]) +
                    " (deep: python -m benchmarks.bench_program)"))

    _section("Dry-run roofline table (from experiments/dryrun)")
    t0 = time.perf_counter()
    try:
        rs = bench_roofline_table.run()
        summary.append(("roofline_table", (time.perf_counter() - t0) * 1e6,
                        f"{len(rs)} cells"))
    except Exception as e:
        print(f"(no dry-run results yet: {e})")

    _section("summary CSV")
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
