"""Open-loop traffic generator for the continuous-batching control plane.

Closed-loop benchmarks (bench_program.run_pool) submit the next request
the moment the last one returns, so the pool is always exactly full and
always lockstep — the best case.  Real serving is open-loop: requests
arrive on their own clock whether or not the server kept up, and greedy
``DevicePool.submit()`` admits each one the moment a slot frees.  Under
staggered arrivals the slots' step offsets desynchronize and, because
the pool advances round by round, the stagger persists for the whole
program: gangs stop forming and throughput collapses to serial.  The
admission window (``core.sched``) exists to fix exactly this; this
module measures by how much.

Two seeded arrival processes (Poisson and bursty) at several offered
loads drive two workloads — the shared-weight matmul graph (the gang
showcase) and persistent-KV decode sessions — through both dispatch
modes:

  * ``greedy``   — straight ``pool.submit()`` at arrival time
  * ``windowed`` — ``Scheduler.submit()`` (bounded admission window,
                   auto or fixed gang width)

and records open-loop latency (arrival -> completion, parking included)
p50/p99 plus aggregate calls/sec per (trace, load, mode) cell into
``benchmarks/BENCH_traffic.json`` — the standing tail-latency wall later
PRs get measured against.  Every completed output is byte-checked
against serial single-device execution before any number is published.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import DevicePool, Program, SchedConfig, Scheduler, hwspec
from repro.core.backend import PallasBackend
from repro.core.scheduler import Epilogue, matmul_reference

POOL_SIZE = 4


# ----------------------------------------------------------------------
# arrival traces (seeded, offsets in seconds from t0)
# ----------------------------------------------------------------------
def poisson_trace(rate_rps: float, n: int, rng: np.random.Generator
                  ) -> np.ndarray:
    """Memoryless arrivals: exponential inter-arrival gaps at
    `rate_rps` mean offered load."""
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def bursty_trace(rate_rps: float, n: int, rng: np.random.Generator,
                 burst: int = POOL_SIZE) -> np.ndarray:
    """Same mean offered load, arriving in bursts of `burst`
    back-to-back requests separated by exponential gaps — the
    flash-crowd shape admission windows are supposed to exploit."""
    gaps = rng.exponential(burst / rate_rps,
                           size=(n + burst - 1) // burst)
    starts = np.cumsum(gaps)
    t = np.repeat(starts, burst)[:n]
    # 50us intra-burst spacing: near-simultaneous, not identical
    return t + np.tile(np.arange(burst) * 50e-6,
                       (len(starts),))[:n]


TRACES: Dict[str, Callable] = {"poisson": poisson_trace,
                               "bursty": bursty_trace}


# ----------------------------------------------------------------------
# open-loop driver
# ----------------------------------------------------------------------
def _drive(submit: Callable[[int], object], offsets: np.ndarray
           ) -> List[tuple]:
    """Replay the trace: sleep to each arrival offset, submit, tag the
    future with its SCHEDULED arrival (open-loop accounting: if the
    driver or server fell behind, the wait still counts against it)."""
    t0 = time.perf_counter()
    out = []
    for i, off in enumerate(offsets):
        delay = t0 + off - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        out.append((t0 + off, submit(i)))
    return out


def _collect(tagged: List[tuple], timeout: float = 600.0) -> dict:
    """Wait for every future, return open-loop latencies + aggregate
    completion rate (first arrival -> last completion)."""
    lats, outs, t_first, t_last = [], [], None, None
    for arrive_at, fut in tagged:
        outs.append(fut.wait(timeout=timeout))
        done_at = fut.done_at
        lats.append(done_at - arrive_at)
        t_first = arrive_at if t_first is None else min(t_first, arrive_at)
        t_last = done_at if t_last is None else max(t_last, done_at)
    lat_ms = np.asarray(lats) * 1e3
    return dict(
        outputs=outs,
        p50_ms=round(float(np.percentile(lat_ms, 50)), 3),
        p99_ms=round(float(np.percentile(lat_ms, 99)), 3),
        calls_per_sec=round(len(tagged) / max(t_last - t_first, 1e-9), 1))


# ----------------------------------------------------------------------
# workload: shared-weight matmul graph
# ----------------------------------------------------------------------
def _build_matmul(spec, rng, m: int = 32, d: int = 64, layers: int = 2):
    """Shared-constant-weight matmul chain with a host stage between
    the layers (the decoder's accel/host/accel shape: think tokenize /
    sample / feature transforms).  The host stage splits the program
    into multiple segments — which is what makes greedy dispatch
    desync-prone: slots parked at different segment offsets stay offset
    forever and stop ganging, the failure mode the admission window
    repairs."""
    ep = Epilogue(shift=6, relu=True)
    ws = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
          for _ in range(layers)]

    def hostfn(a):
        return np.ascontiguousarray(a[::-1])    # cheap, deterministic

    p = Program(spec)
    t = p.input("x", (m, d))
    for i, w in enumerate(ws):
        t = p.matmul(t, p.constant(f"w{i}", w), epilogue=ep)
        if i < len(ws) - 1:
            t = p.host(hostfn, t, shape=(m, d), kind="mat")
    compiled = p.compile(use_cache=False)

    def ref(x):
        r = x
        for i, w in enumerate(ws):
            r = matmul_reference(r, w, ep)
            if i < len(ws) - 1:
                r = hostfn(r)
        return r
    return compiled, ref, (m, d)


def _warm_gang_widths(compiled, eng, feed: Dict[str, np.ndarray],
                      sessions: bool = False) -> None:
    """JIT-warm every gang width 1..POOL_SIZE deterministically: a
    fixed-width scheduler releases exact gangs of each width (each
    width is a distinct vmapped kernel shape — unwarmed widths would
    charge their compile to whichever measured cell hits them first)."""
    with DevicePool(compiled, size=POOL_SIZE, backend=eng) as pool:
        for w in range(1, POOL_SIZE + 1):
            s = Scheduler(pool, SchedConfig(
                window_us=100000, gang_width=w, pipeline_depth=1))
            if sessions:   # stateful warm: throwaway pool, state discarded
                futs = [s.session(slot=i).submit(**feed)
                        for i in range(w)]
            else:
                futs = [s.submit(**feed) for _ in range(w)]
            [f.wait(timeout=600) for f in futs]
            s.close()


def run_matmul_traffic(n_requests: int = 48,
                       loads: Optional[Dict[str, float]] = None,
                       traces: tuple = ("poisson", "bursty"),
                       window_us: float = 2000.0, reps: int = 3,
                       seed: int = 20260808, quiet: bool = False) -> dict:
    """Drive the shared-weight matmul graph open-loop.  `loads` maps a
    label to an offered-load multiple of the pool's calibrated
    aggregate capacity (None -> moderate 0.75x and high 1.5x); every
    (trace, load) cell runs greedy AND windowed, best-of-`reps` on
    calls/sec (cold-start noise suppression, same as the other
    benchmarks), and byte-checks EVERY repetition against serial
    execution."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(seed)
    compiled, ref, (m, d) = _build_matmul(spec, rng)
    eng = PallasBackend()
    probe = {"x": rng.integers(-128, 128, size=(m, d), dtype=np.int8)}
    _warm_gang_widths(compiled, eng, probe)

    # calibrate: serial per-call seconds on this machine (warm)
    t0 = time.perf_counter()
    for _ in range(5):
        compiled(backend=eng, **probe)
    t_call = (time.perf_counter() - t0) / 5
    slot_rps = 1.0 / max(t_call, 1e-9)
    if loads is None:
        loads = {"moderate": 0.75, "high": 1.5}

    feeds = [rng.integers(-128, 128, size=(m, d), dtype=np.int8)
             for _ in range(n_requests)]
    refs = [ref(x) for x in feeds]

    result = {"workload": f"matmul {m}x{d} chain, shared constant "
                          f"weights + host mid-stage, pool {POOL_SIZE}",
              "pool_size": POOL_SIZE,
              "serial_slot_rps": round(slot_rps, 1),
              "window_us": window_us, "n_requests": n_requests,
              "reps_best_of": reps, "traces": {}}
    trace_rng = np.random.default_rng(seed + 1)
    for trace in traces:
        for label, mult in loads.items():
            rate = slot_rps * POOL_SIZE * mult
            offsets = TRACES[trace](rate, n_requests,
                                    np.random.default_rng(
                                        trace_rng.integers(1 << 31)))
            cell = {"offered_rps": round(rate, 1), "modes": {}}
            for mode in ("greedy", "windowed"):
                best = None
                for _ in range(reps):
                    with DevicePool(compiled, size=POOL_SIZE,
                                    backend=eng) as pool:
                        sched = None
                        if mode == "windowed":
                            sched = Scheduler(pool, SchedConfig(
                                window_us=window_us, queue_cap=4096))
                            submit = lambda i: sched.submit(x=feeds[i])
                        else:
                            submit = lambda i: pool.submit(x=feeds[i])
                        tagged = _drive(submit, offsets)
                        got = _collect(tagged)
                        outs = got.pop("outputs")
                        for o, r in zip(outs, refs):
                            assert np.array_equal(o, r), \
                                f"{mode}/{trace}/{label}: output " \
                                "diverged from serial baseline — " \
                                "refusing to publish"
                        got["exact"] = True
                        stats = pool.slot_stats()
                        got["ganged_steps"] = sum(s.ganged_steps
                                                  for s in stats)
                        got["max_gang"] = max(s.max_gang for s in stats)
                        if sched is not None:
                            st = sched.stats()[0]
                            got["releases"] = st.releases
                            got["window_timeouts"] = st.window_timeouts
                            got["gang_width"] = sched.gang_widths[0]
                            sched.close()
                        if best is None or got["calls_per_sec"] > \
                                best["calls_per_sec"]:
                            best = got
                cell["modes"][mode] = best
            g = cell["modes"]["greedy"]["calls_per_sec"]
            w = cell["modes"]["windowed"]["calls_per_sec"]
            cell["windowed_vs_greedy_x"] = round(w / max(g, 1e-9), 2)
            result["traces"][f"{trace}@{label}"] = cell
            if not quiet:
                print(f"  {trace:>8}@{label:<9} "
                      f"({cell['offered_rps']:>7} rps offered): "
                      f"greedy {g:>7} c/s "
                      f"p99 {cell['modes']['greedy']['p99_ms']:>8}ms | "
                      f"windowed {w:>7} c/s "
                      f"p99 {cell['modes']['windowed']['p99_ms']:>8}ms | "
                      f"{cell['windowed_vs_greedy_x']}x")
    return result


# ----------------------------------------------------------------------
# workload: persistent-KV decode sessions
# ----------------------------------------------------------------------
def run_decode_traffic(sessions: int = POOL_SIZE, steps: int = 8,
                       loads: Optional[Dict[str, float]] = None,
                       trace: str = "poisson",
                       window_us: float = 3000.0, reps: int = 2,
                       seed: int = 20260809, quiet: bool = False) -> dict:
    """Token-arrival traffic for `sessions` concurrent decode sessions
    (quantized decoder, persistent KV caches).  Arrivals round-robin the
    sessions; a session's next token waits for its predecessor (state
    order), but latency is charged from the scheduled arrival — the
    open-loop convention.  Windowed mode routes submits through the
    admission window so same-step tokens of different sessions release
    (and gang) together."""
    from repro.models.vta_decoder import QuantDecoder

    dec = QuantDecoder()
    if 2 + steps > dec.cfg.s_max:
        raise ValueError(f"steps {steps} + warmup exceed KV capacity "
                         f"{dec.cfg.s_max}")
    compiled = dec.compile(use_cache=False)
    eng = PallasBackend()
    n = sessions * steps
    rng = np.random.default_rng(seed)
    toks = [rng.integers(-32, 32, (1, dec.cfg.d_model), np.int8)
            for _ in range(n)]
    _warm_gang_widths(compiled, eng, {"x": toks[0]}, sessions=True)

    # calibrate one serial decode step (pool of 1, warm)
    with DevicePool(compiled, size=1, backend=eng) as p1:
        s = p1.session()
        s.submit(x=toks[0]).wait(timeout=600)
        t0 = time.perf_counter()
        s.submit(x=toks[1]).wait(timeout=600)
        t_step = time.perf_counter() - t0
    step_rps = 1.0 / max(t_step, 1e-9)
    if loads is None:
        loads = {"moderate": 0.5, "overload": 1.5}

    result = {"workload": f"quantized {dec.cfg.n_blocks}-block decoder, "
                          f"{sessions} sessions x {steps} tokens, "
                          f"pool {POOL_SIZE}",
              "pool_size": POOL_SIZE, "window_us": window_us,
              "serial_step_rps": round(step_rps, 1),
              "reps_best_of": reps, "traces": {}}
    for label, mult in loads.items():
        rate = step_rps * POOL_SIZE * mult
        offsets = TRACES[trace](rate, n, np.random.default_rng(seed + 2))
        cell = {"offered_rps": round(rate, 1), "modes": {}}
        for mode in ("greedy", "windowed"):
            best = None
            for _ in range(reps):
                with DevicePool(compiled, size=POOL_SIZE,
                                backend=eng) as pool:
                    sched = None
                    if mode == "windowed":
                        sched = Scheduler(pool, SchedConfig(
                            window_us=window_us, queue_cap=4096))
                        sess = [sched.session(slot=i % POOL_SIZE)
                                for i in range(sessions)]
                    else:
                        sess = [pool.session(slot=i % POOL_SIZE)
                                for i in range(sessions)]
                    refs = [dec.reference() for _ in range(sessions)]
                    # warm this pool's sessions (tokens 0..sessions-1
                    # are the warmup prefix of the reference streams)
                    wf = [sess[i].submit(x=toks[i])
                          for i in range(sessions)]
                    for i, f in enumerate(wf):
                        assert np.array_equal(f.wait(timeout=600),
                                              refs[i].step(toks[i]))
                    last: List[object] = list(wf)

                    def submit(i, _sess=sess, _last=last):
                        si = i % sessions
                        if _last[si] is not None and not _last[si].done():
                            _last[si].wait(timeout=600)   # state order
                        f = _sess[si].submit(x=toks[sessions + i])
                        _last[si] = f
                        return f

                    tagged = _drive(submit, offsets[:n - sessions])
                    got = _collect(tagged)
                    outs = got.pop("outputs")
                    for i, o in enumerate(outs):
                        r = refs[i % sessions].step(toks[sessions + i])
                        assert np.array_equal(o, r), \
                            f"{mode}/{label}: decode step {i} diverged " \
                            "from the eager reference — refusing to " \
                            "publish"
                    got["exact"] = True
                    stats = pool.slot_stats()
                    got["ganged_steps"] = sum(s.ganged_steps
                                              for s in stats)
                    got["max_gang"] = max(s.max_gang for s in stats)
                    if sched is not None:
                        st = sched.stats()[0]
                        got["releases"] = st.releases
                        got["window_timeouts"] = st.window_timeouts
                        sched.close()
                    if best is None or got["calls_per_sec"] > \
                            best["calls_per_sec"]:
                        best = got
            cell["modes"][mode] = best
        g = cell["modes"]["greedy"]["calls_per_sec"]
        w = cell["modes"]["windowed"]["calls_per_sec"]
        cell["windowed_vs_greedy_x"] = round(w / max(g, 1e-9), 2)
        result["traces"][f"{trace}@{label}"] = cell
        if not quiet:
            print(f"  decode {trace:>8}@{label:<9} "
                  f"({cell['offered_rps']:>6} rps offered): "
                  f"greedy {g:>6} t/s | windowed {w:>6} t/s | "
                  f"{cell['windowed_vs_greedy_x']}x")
    return result


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def run_traffic(out_json: Optional[str] = None, smoke: bool = False,
                quiet: bool = False) -> dict:
    """Full open-loop traffic benchmark: both workloads, >= 2 traces x
    >= 2 offered loads, greedy vs windowed, everything byte-checked.
    Writes ``benchmarks/BENCH_traffic.json`` (full mode only).

    `smoke` shrinks to one tiny trace per workload and skips the JSON —
    the CI mode: it proves exactness-through-the-scheduler and the
    plumbing, not the performance claim."""
    if not quiet:
        print("open-loop traffic (greedy submit vs admission window):")
    if smoke:
        mat = run_matmul_traffic(n_requests=8, loads={"smoke": 1.0},
                                 traces=("poisson",), reps=1,
                                 quiet=quiet)
        dec = run_decode_traffic(sessions=2, steps=2, reps=1,
                                 loads={"smoke": 1.0}, quiet=quiet)
        return {"smoke": True, "matmul": mat, "decode": dec}
    result = {"pool_size": POOL_SIZE, "workloads": {}}
    result["workloads"]["matmul-shared-weights"] = run_matmul_traffic(
        quiet=quiet)
    result["workloads"]["decode-sessions"] = run_decode_traffic(
        quiet=quiet)
    if out_json is None:
        out_json = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_traffic.json")
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    if not quiet:
        print(f"-> {out_json}")
    return result


if __name__ == "__main__":
    run_traffic()
