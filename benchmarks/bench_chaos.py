"""Chaos benchmark: goodput under seeded fault injection.

The self-healing machinery (slot respawn, request retry, integrity
restage, session checkpoint/restore) is only worth its complexity if a
pool under a realistic fault rate still delivers most of its fault-free
throughput — recovery that serializes the pool or thrashes respawns
would be worse than failing fast.  This benchmark pins that down:

  * ``baseline`` — the shared-weight matmul graph (accel/host/accel,
    the gang showcase) served closed-loop through a plain 4-slot pool.
  * ``chaos``    — the same requests through a pool armed with
    ``max_respawns``/``retries``/``integrity`` while a seeded
    :class:`FaultPlan` injects kills, constant-DRAM bit flips, and gang
    delays at a 10% per-gang rate.

Every surviving output is byte-checked against fault-free serial
execution before any number is published, every loss must be a typed
error, and the pool's fault log must reconcile exactly with the plan's
fired entries.  Reported: goodput (completed requests/sec) for both
runs, their ratio (the acceptance bar: >= 0.80 in the full run),
recovery p99 (submit->done latency over requests that needed more than
one attempt; includes queueing — the number a caller actually
experiences), and the recovery counters (deaths / respawns / retries /
integrity restages).  Full mode writes ``benchmarks/BENCH_chaos.json``.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

import numpy as np

from repro.core import DevicePool, SchedConfig, Scheduler, hwspec
from repro.core.backend import PallasBackend
from repro.core.chaos import FaultPlan
from repro.core.serve import PoolClosed, SlotDied

from benchmarks.loadgen import POOL_SIZE, _build_matmul, _warm_gang_widths

FAULT_RATE = 0.10
MIN_GOODPUT_RATIO = 0.80


def _closed_loop(compiled, eng, feeds: List[np.ndarray],
                 refs: List[np.ndarray], pool_kwargs: dict,
                 label: str) -> dict:
    """Submit every request up front through the windowed Scheduler
    (the production control plane: the admission window re-forms gangs
    after a respawned slot rejoins out of step — raw greedy submit
    would stay desynced for the rest of the run), wait them all,
    byte-check every survivor against the fault-free serial reference,
    and account survivors / typed losses / per-request latency.  A hang
    in ``wait`` fails the run — recovery must never leave a future
    unresolved."""
    with DevicePool(compiled, size=POOL_SIZE, backend=eng,
                    **pool_kwargs) as pool:
        sched = Scheduler(pool, SchedConfig(window_us=2000.0,
                                            queue_cap=4096))
        t0 = time.perf_counter()
        tagged = [(time.perf_counter(), sched.submit(x=x)) for x in feeds]
        outs, losses, retried_lats = [], 0, []
        for t_sub, f in tagged:
            try:
                o = f.wait(timeout=600)
            except (SlotDied, PoolClosed) as e:
                losses += 1
                outs.append(None)
                assert getattr(e, "attempts", 1) >= 1
                continue
            outs.append(o)
            pf = f.pool_future
            if pf is not None and pf.attempts > 1:
                retried_lats.append(f.done_at - t_sub)
        wall = time.perf_counter() - t0
        sched.close()
        stats = pool.slot_stats()
        log = list(pool.fault_log)
    survivors = 0
    for i, o in enumerate(outs):
        if o is None:
            continue
        survivors += 1
        assert np.array_equal(o, refs[i]), \
            f"{label} req={i}: output diverged from fault-free " \
            "serial — refusing to publish"
    return dict(
        fault_log=log,
        wall_s=round(wall, 3),
        goodput_rps=round(survivors / max(wall, 1e-9), 1),
        survivors=survivors, losses=losses,
        retried=len(retried_lats),
        recovery_p99_ms=(round(float(np.percentile(
            np.asarray(retried_lats) * 1e3, 99)), 2)
            if retried_lats else None),
        deaths=sum(s.deaths for s in stats),
        respawns=sum(s.respawns for s in stats),
        integrity_restages=sum(s.integrity_restages for s in stats))


def run(n_requests: int = 64, rate: float = FAULT_RATE,
        seed: int = 20260811, reps: int = 3, smoke: bool = False,
        out_json: Optional[str] = None, quiet: bool = False) -> dict:
    """Fault-free baseline vs chaos run on identical request streams,
    best-of-`reps` on goodput (cold-start noise suppression, same
    convention as the other benchmarks; every repetition is
    byte-checked).  `smoke` shrinks the stream and skips the JSON + the
    goodput-ratio assertion (CI proves exactness and typed accounting,
    not the performance claim)."""
    if smoke:
        n_requests, reps = min(n_requests, 12), 1
    spec = hwspec.pynq()
    rng = np.random.default_rng(seed)
    compiled, ref, (m, d) = _build_matmul(spec, rng)
    eng = PallasBackend()
    feeds = [rng.integers(-128, 128, size=(m, d), dtype=np.int8)
             for _ in range(n_requests)]
    refs = [ref(x) for x in feeds]
    _warm_gang_widths(compiled, eng, {"x": feeds[0]})

    base = None
    for _ in range(reps):
        r = _closed_loop(compiled, eng, feeds, refs, {}, "baseline")
        assert r["losses"] == 0, "fault-free baseline lost requests"
        if base is None or r["goodput_rps"] > base["goodput_rps"]:
            base = r

    # calibrate the delay-fault magnitude to the measured per-gang
    # service time (~2x a gang): a "delay" models a stall the pool rides
    # out, not an outage — outages are the watchdog's department (the
    # chaos tests exercise it); a fixed multi-gang sleep would measure
    # the sleep, not the recovery machinery
    n_gangs = 8 * n_requests
    gang_s = base["wall_s"] / max(n_requests, 1)
    max_delay_s = round(max(2.0 * gang_s, 1e-3), 4)

    chaos = None
    for _ in range(reps):
        # same seed -> the identical deterministic plan every repetition
        plan = FaultPlan.random(seed=seed + 1, n_gangs=n_gangs,
                                slots=POOL_SIZE, rate=rate,
                                max_delay_s=max_delay_s)
        r = _closed_loop(compiled, eng, feeds, refs, dict(
            max_respawns=8, retries=3, retry_backoff_s=0.002,
            integrity=True, fault_plan=plan), "chaos")
        assert len(r["fault_log"]) == len(plan.fired), \
            "pool fault log does not reconcile with the plan's " \
            "fired faults"
        r["faults_fired"] = plan.fired_counts()
        if chaos is None or r["goodput_rps"] > chaos["goodput_rps"]:
            chaos = r
    chaos.pop("fault_log")
    base.pop("fault_log")

    ratio = round(chaos["goodput_rps"] / max(base["goodput_rps"], 1e-9), 3)
    result = {
        "workload": f"matmul {m}x{d} chain + host mid-stage, "
                    f"pool {POOL_SIZE}, closed loop",
        "pool_size": POOL_SIZE, "n_requests": n_requests,
        "fault_rate_per_gang": rate, "seed": seed,
        "reps_best_of": reps, "max_delay_s": max_delay_s,
        "recovery_config": dict(max_respawns=8, retries=3,
                                retry_backoff_s=0.002, integrity=True),
        "baseline": base, "chaos": chaos,
        "goodput_ratio": ratio, "exact": True, "smoke": smoke}
    if not quiet:
        print(f"  baseline  {base['goodput_rps']:>7} req/s "
              f"({base['wall_s']}s, {n_requests} requests)")
        print(f"  chaos     {chaos['goodput_rps']:>7} req/s "
              f"({chaos['wall_s']}s, {chaos['survivors']} survived / "
              f"{chaos['losses']} typed losses, "
              f"{chaos['deaths']} deaths / {chaos['respawns']} respawns, "
              f"{chaos['retried']} retried, "
              f"{chaos['integrity_restages']} restages, "
              f"fired={chaos['faults_fired']})")
        print(f"  goodput ratio {ratio} (bar {MIN_GOODPUT_RATIO}), "
              f"recovery p99 {chaos['recovery_p99_ms']}ms")
    if not smoke:
        assert ratio >= MIN_GOODPUT_RATIO, \
            f"chaos goodput ratio {ratio} below the " \
            f"{MIN_GOODPUT_RATIO} acceptance bar"
        if out_json is None:
            out_json = os.path.join(
                os.path.dirname(os.path.abspath(__file__)),
                "BENCH_chaos.json")
        with open(out_json, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        if not quiet:
            print(f"-> {out_json}")
    return result


if __name__ == "__main__":
    run()
